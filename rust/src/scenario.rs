//! Scenario plumbing shared by the CLI, examples and benches: artifact
//! loading, backend choice (real PJRT vs surrogate), workload construction,
//! one-call experiment runs, and the concurrent scenario-sweep entry point.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::baselines;
use crate::coordinator::backend::{
    MemoBackend, ParallelBackend, RealBackend, SurrogateBackend, TextBackend,
};
use crate::coordinator::{EngineCfg, RunError};
use crate::corpus::workload::{Arrival, Workload, WorkloadSpec};
use crate::costmodel::{CalibMode, CalibState, CalibStore};
use crate::corpus::Corpus;
use crate::fleet::{Fleet, FleetCfg};
use crate::metrics::{RequestTrace, RunMetrics};
use crate::models::Registry;
use crate::quality::judge::Judge;
use crate::serve::{PiceService, ServeCfg};
use crate::sweep::cache::{load_snapshot, CacheStats, SharedMemoCache, SnapshotState};
use crate::sweep::{ScenarioResult, SweepRunner, SweepScenario};
use crate::tokenizer::Tokenizer;

/// Builds a fresh replica of the substrate backend (real PJRT or surrogate)
/// — no cache layer. Called once per `ParallelBackend` worker and once per
/// sweep scenario.
type ReplicaFactory = dyn Fn() -> Box<dyn TextBackend + Send> + Send + Sync;

/// Everything a scenario needs, loaded once per process.
///
/// The generation cache is a process-wide [`SharedMemoCache`]: the
/// sequential [`Env::run`] path and every concurrent [`Env::run_sweep`]
/// scenario all hit the same store, so cross-variant replays (Fig. 6's four
/// systems answering the same questions with the same derived seeds) are
/// hits no matter which variant generated first. With `PICE_MEMO_PATH` set
/// the paged store is attached ONCE here (manifest only — pages fault in on
/// demand) and dirty pages are saved ONCE when the `Env` drops — not once
/// per run.
pub struct Env {
    pub tok: Tokenizer,
    pub corpus: Arc<Corpus>,
    pub registry: Registry,
    pub backend: Box<dyn TextBackend>,
    pub judge: Judge,
    pub real: bool,
    cache: Option<Arc<SharedMemoCache>>,
    snapshot: Option<SnapshotState>,
    /// `PICE_CALIB_PATH` cost-model calibration store (same artifact stamp
    /// as the memo snapshot). Loaded once here, saved once on drop.
    calib: Option<CalibStore>,
    replica: Arc<ReplicaFactory>,
    /// `PICE_WORKERS` when the user set it explicitly. Sweep scenarios
    /// honor an explicit worker count (each scenario's backend becomes its
    /// own pool); auto-sizing applies only to the sequential backend —
    /// during a sweep, cross-scenario parallelism already fills the host.
    explicit_workers: Option<usize>,
    /// next cache-owner id handed to a sweep scenario — monotone across
    /// `run_sweep` calls, so variants of successive sweeps never share an
    /// owner and cross-variant hits are attributed correctly.
    next_owner: AtomicU32,
}

impl Env {
    /// Load artifacts + the real PJRT backend; fall back to the Rust synth
    /// corpus + surrogate backend when artifacts are missing or
    /// `PICE_BACKEND=surrogate`.
    ///
    /// Execution-layer knobs (all preserve bit-identical outputs):
    /// * `PICE_WORKERS=N` — shard backend batches over N OS threads via
    ///   [`ParallelBackend`], each worker owning its own backend replica
    ///   (surrogate clone / separately-loaded PJRT models). Unset (or
    ///   unparsable) auto-sizes from the host — see [`auto_workers`].
    /// * `PICE_SWEEP_THREADS=N` — scenario-sweep pool size for
    ///   [`Env::run_sweep`] (unset auto-sizes the same way).
    /// * `PICE_MEMO_CAP=N` (default 4096; 0 disables) — entry-count bound
    ///   of the shared generation memo-cache.
    /// * `PICE_CACHE_BUDGET=bytes` (optional `k`/`m`/`g` suffix; 0
    ///   disables the cache) — hard RESIDENT-BYTE budget for the cache's
    ///   buffer pool instead of the entry cap; cold pages are evicted by a
    ///   clock policy and, with `PICE_MEMO_PATH` set, spilled to disk
    ///   rather than discarded (see PERF.md §Buffer-pool store). Takes
    ///   precedence over `PICE_MEMO_CAP`; an unparsable value is an error.
    /// * `PICE_MEMO_PATH=path` — persist the shared cache to a
    ///   stamp-guarded paged store at `path` (a directory), so separate
    ///   bench processes share one cache; only the manifest is read at
    ///   load, pages fault in on demand (see PERF.md §Persistent cache).
    ///   A pre-existing v1 monolithic snapshot file at `path` is imported
    ///   once and converted in place.
    /// * `PICE_CALIB_PATH=path` — persist learned cost-model calibration
    ///   to a stamp-guarded store at `path`; `--calibrate warm` /
    ///   [`Env::apply_calib`] warm-start from it (PERF.md §Calibrated cost
    ///   model). Calibration *knobs* (`PICE_CALIB_*`) are overlaid by the
    ///   CLI via [`crate::costmodel::CalibCfg::overlay_env`], not here.
    pub fn load() -> Result<Env, String> {
        let art = crate::artifacts_dir();
        let force_surrogate = std::env::var("PICE_BACKEND").as_deref() == Ok("surrogate");
        let have_artifacts = art.join("manifest.json").exists();
        let env_usize = |key: &str, default: usize| {
            std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
        };
        let explicit_workers: Option<usize> =
            std::env::var("PICE_WORKERS").ok().and_then(|v| v.parse().ok());
        let workers = explicit_workers.unwrap_or_else(auto_workers);
        let memo_cap = env_usize("PICE_MEMO_CAP", 4096);
        // strict parse: a typo'd budget silently falling back to the entry
        // cap would be a memory-limit violation, not a degraded mode
        let cache_budget = match std::env::var("PICE_CACHE_BUDGET") {
            Ok(v) => Some(crate::store::parse_byte_size(&v).ok_or_else(|| {
                format!(
                    "PICE_CACHE_BUDGET: unparsable byte size {v:?} \
                     (use e.g. 4096, 512k, 64m, 2g; 0 disables the cache)"
                )
            })?),
            Err(_) => None,
        };
        let memo_path = std::env::var("PICE_MEMO_PATH").ok().filter(|p| !p.is_empty());

        let (tok, corpus, registry, real, stamp, first, replica) = if have_artifacts
            && !force_surrogate
        {
            let tok = Tokenizer::from_file(&art.join("vocab.json"))?;
            let corpus = Arc::new(Corpus::from_file(&art.join("corpus.json"), &tok)?);
            let registry = Registry::from_artifacts(&art)?;
            let stamp = real_cache_stamp(&art);
            let eos = tok.specials.eos;
            // the probe doubles as the first replica: a broken setup fails
            // here (not inside a worker thread), and the model load is
            // reused instead of repeated
            let first: Box<dyn TextBackend + Send> = Box::new(RealBackend::new(&art, eos)?);
            let art2 = art.clone();
            let replica: Arc<ReplicaFactory> = Arc::new(move || {
                Box::new(RealBackend::new(&art2, eos).expect("backend replica"))
                    as Box<dyn TextBackend + Send>
            });
            (tok, corpus, registry, true, stamp, first, replica)
        } else {
            let tok = crate::corpus::synth::synth_tokenizer();
            let corpus = Arc::new(crate::corpus::synth::synth_corpus(&tok, 30, 42));
            let registry = Registry::builtin();
            let base = SurrogateBackend::new(corpus.clone(), &tok, &registry, SURROGATE_SEED);
            let stamp = surrogate_cache_stamp(&tok, &corpus, &registry, SURROGATE_SEED);
            let first: Box<dyn TextBackend + Send> = Box::new(base.clone());
            let replica: Arc<ReplicaFactory> =
                Arc::new(move || Box::new(base.clone()) as Box<dyn TextBackend + Send>);
            (tok, corpus, registry, false, stamp, first, replica)
        };

        let cache = match cache_budget {
            // byte budget wins over the entry cap; 0 = cache off
            Some(0) => None,
            Some(bytes) => {
                Some(Arc::new(SharedMemoCache::with_cfg(crate::store::PoolCfg::byte_budget(bytes))))
            }
            None => (memo_cap > 0).then(|| Arc::new(SharedMemoCache::new(memo_cap))),
        };
        let snapshot = match (&cache, memo_path) {
            (Some(c), Some(p)) => Some(load_snapshot(c, p, &stamp)),
            _ => None,
        };
        let calib = std::env::var("PICE_CALIB_PATH")
            .ok()
            .filter(|p| !p.is_empty())
            .map(|p| CalibStore::load(p, &stamp));
        // The sequential backend stack: (memo over) parallel pool or the
        // probe replica. Sweep scenarios build their own stacks over the
        // same shared cache — see run_sweep.
        let inner: Box<dyn TextBackend + Send> = if workers > 1 {
            let r = replica.clone();
            let mut first = Some(first);
            // the probe serves as worker 0's replica — `workers` loads
            // total, not workers + 1
            Box::new(ParallelBackend::new(workers, move |_| {
                first.take().unwrap_or_else(|| r())
            }))
        } else {
            first
        };
        let backend: Box<dyn TextBackend> = match &cache {
            Some(c) => Box::new(MemoBackend::shared(inner, c.clone(), ENV_SEQ_OWNER)),
            None => inner,
        };
        let judge = Judge::fit(&corpus);
        Ok(Env {
            tok,
            corpus,
            registry,
            backend,
            judge,
            real,
            cache,
            snapshot,
            calib,
            replica,
            explicit_workers,
            next_owner: AtomicU32::new(1),
        })
    }

    /// (hits, misses) of the shared generation cache, if enabled.
    pub fn memo_stats(&self) -> Option<(u64, u64)> {
        self.cache_stats().map(|s| (s.hits, s.misses))
    }

    /// Full lookup counters of the shared cache, including cross-variant
    /// hits (entries inserted by one sweep scenario and served to another).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    /// Entries restored from the `PICE_MEMO_PATH` snapshot at load (None
    /// when persistence is off).
    pub fn restored_entries(&self) -> Option<usize> {
        self.snapshot.as_ref().map(SnapshotState::restored_entries)
    }

    /// Write the shared cache back to its snapshot, if persistence is on
    /// and the cache gained entries. Called automatically on drop; call
    /// explicitly to flush earlier.
    pub fn save_cache(&mut self) -> Result<(), String> {
        if let (Some(cache), Some(snap)) = (&self.cache, &mut self.snapshot) {
            if snap.dirty(cache) {
                snap.save(cache)?;
            }
        }
        Ok(())
    }

    /// Apply a calibration mode to a config: `Warm` additionally seeds the
    /// model from the `PICE_CALIB_PATH` store's entry for this config's
    /// shape ([`EngineCfg::calib_key`]) — a missing entry (or no store)
    /// degrades to a cold calibrated start, never an error.
    pub fn apply_calib(&self, cfg: &mut EngineCfg, mode: CalibMode) {
        cfg.calib.mode = mode;
        cfg.calib.warm = match mode {
            CalibMode::Warm => self.calib_warm(cfg),
            _ => None,
        };
    }

    /// Warm-start state stored for this config's shape, if any.
    pub fn calib_warm(&self, cfg: &EngineCfg) -> Option<CalibState> {
        self.calib.as_ref().and_then(|s| s.get(&cfg.calib_key()))
    }

    /// Deposit an end-of-run calibration state under `key` (no-op when
    /// persistence is off or the engine learned nothing — `state` is
    /// `None` on static models).
    pub fn calib_record(&mut self, key: &str, state: Option<CalibState>) {
        if let (Some(store), Some(st)) = (&mut self.calib, state) {
            store.put(key, st);
        }
    }

    /// Calibration entries restored from the `PICE_CALIB_PATH` store at
    /// load (None when calibration persistence is off).
    pub fn calib_restored(&self) -> Option<usize> {
        self.calib.as_ref().map(CalibStore::restored_entries)
    }

    /// Write the calibration store back, if persistence is on and new
    /// state was deposited. Called automatically on drop.
    pub fn save_calib(&mut self) -> Result<(), String> {
        if let Some(store) = &mut self.calib {
            if store.dirty() {
                store.save()?;
            }
        }
        Ok(())
    }

    /// Paper §V-B workload: RPM = 1.5 x the cloud model's max batch.
    pub fn paper_rpm(&self, cloud_model: &str) -> f64 {
        let info = self.registry.get(cloud_model).expect("model");
        let cloud = crate::cluster::DeviceSpec::a100_cloud("c");
        1.5 * cloud.max_batch(info, 1000) as f64
    }

    pub fn workload(&self, rpm: f64, n: usize, seed: u64) -> Workload {
        self.workload_with(WorkloadSpec {
            rpm,
            n_requests: n,
            arrival: Arrival::Poisson,
            categories: vec![],
            seed,
        })
    }

    /// Workload from an explicit spec — e.g. pairing
    /// [`Arrival::BurstyPoisson`] load spikes with a dynamics scenario's
    /// link degradation (the fig_dynamics composition).
    pub fn workload_with(&self, spec: WorkloadSpec) -> Workload {
        Workload::generate(&self.corpus, spec)
    }

    /// Run one engine configuration over a workload — the sequential
    /// closed-loop driver ([`crate::coordinator::Engine::run`] submits every
    /// arrival into the step-driven core and drains it to quiescence).
    pub fn run(
        &mut self,
        cfg: EngineCfg,
        wl: &Workload,
    ) -> Result<(RunMetrics, Vec<RequestTrace>), RunError> {
        let mut engine = crate::coordinator::Engine::new(
            cfg,
            self.corpus.clone(),
            &self.tok,
            &self.registry,
            self.backend.as_mut(),
        )?;
        let traces = engine.run(wl)?;
        Ok((crate::metrics::aggregate(&traces), traces))
    }

    /// Open a streaming serving session façade over this environment's
    /// backend: `submit()` requests open-loop as they arrive, pump simulated
    /// time forward, and poll per-request [`crate::serve::ResponseEvent`]s
    /// (sketch first, expansions behind it, exactly one terminal event).
    /// Driving a workload's arrivals through the service produces traces
    /// bit-identical to [`Env::run`] on the same `(cfg, workload)`.
    pub fn service(
        &mut self,
        cfg: EngineCfg,
        serve_cfg: ServeCfg,
    ) -> Result<PiceService<'_>, RunError> {
        let engine = crate::coordinator::Engine::new(
            cfg,
            self.corpus.clone(),
            &self.tok,
            &self.registry,
            self.backend.as_mut(),
        )?;
        Ok(PiceService::new(engine, serve_cfg))
    }

    /// Open a streaming service over a sharded fleet: `fleet_cfg.shards`
    /// engines, each owning its own backend replica stack (worker pool when
    /// `PICE_WORKERS > 1` is set explicitly, like sweep scenarios) tagged
    /// with its own cache-owner id over the shared memo cache — so
    /// [`Env::cache_stats`] afterwards shows `cross_hits` when one shard's
    /// generations serve another's. With `shards == 1` and hash placement
    /// the service is bit-identical to [`Env::service`] on the same
    /// `(cfg, workload)`.
    pub fn fleet_service(
        &self,
        cfg: EngineCfg,
        serve_cfg: ServeCfg,
        fleet_cfg: FleetCfg,
    ) -> Result<PiceService<'_>, RunError> {
        let n = fleet_cfg.shards.max(1);
        let workers = self.explicit_workers.unwrap_or(1);
        let base = self.next_owner.fetch_add(n as u32, Ordering::Relaxed);
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let inner: Box<dyn TextBackend + Send> = if workers > 1 {
                let r = self.replica.clone();
                Box::new(ParallelBackend::new(workers, move |_| r()))
            } else {
                (self.replica)()
            };
            let backend: Box<dyn TextBackend> = match &self.cache {
                Some(c) => Box::new(MemoBackend::shared(inner, c.clone(), base + i as u32)),
                None => inner,
            };
            shards.push(crate::coordinator::Engine::new_owned(
                crate::fleet::shard_cfg(&cfg, i),
                self.corpus.clone(),
                &self.tok,
                &self.registry,
                backend,
            )?);
        }
        let mut fleet = Fleet::new(shards, fleet_cfg.placement);
        if cfg.tail.on() {
            // tail tolerance extends to the fleet tier: a dead shard's
            // displaced sessions are stolen by healthy peers at pump time
            fleet.enable_rebalance();
        }
        Ok(PiceService::over_fleet(fleet, serve_cfg))
    }

    /// Run a grid of independent scenarios across the sweep thread pool
    /// (`PICE_SWEEP_THREADS`, auto-sized when unset). `results[i]`
    /// corresponds to `scenarios[i]`, and the output is bit-identical to
    /// calling [`Env::run`] in a loop — each scenario is a pure function of
    /// `(cfg, workload, seed)` and the shared cache is transparent.
    ///
    /// Every scenario gets its own backend replica tagged with its own
    /// cache-owner id, so [`Env::cache_stats`] afterwards reports how much
    /// the variants served each other (`cross_hits`).
    pub fn run_sweep(&self, scenarios: &[SweepScenario]) -> Vec<ScenarioResult> {
        self.run_sweep_with(&SweepRunner::from_env(), scenarios)
    }

    /// [`Env::run_sweep`] with an explicit runner (thread-count control for
    /// benches measuring sweep scaling).
    ///
    /// An *explicitly set* `PICE_WORKERS > 1` stacks: each scenario's
    /// backend becomes its own worker pool under the shared memo handle
    /// (sweep threads × workers OS threads — the user asked for it). When
    /// `PICE_WORKERS` is unset, scenarios run single-replica backends:
    /// auto-sized batch sharding would only oversubscribe a host the sweep
    /// pool already fills.
    pub fn run_sweep_with(
        &self,
        runner: &SweepRunner,
        scenarios: &[SweepScenario],
    ) -> Vec<ScenarioResult> {
        let replica = self.replica.clone();
        let cache = self.cache.clone();
        let workers = self.explicit_workers.unwrap_or(1);
        // owner 0 is the Env's own sequential backend; sweep owners are
        // allocated monotonically so scenarios of DIFFERENT sweeps never
        // alias and cross-variant attribution stays exact
        let base = self.next_owner.fetch_add(scenarios.len().max(1) as u32, Ordering::Relaxed);
        let factory = move |i: usize| -> Box<dyn TextBackend> {
            let inner: Box<dyn TextBackend + Send> = if workers > 1 {
                let r = replica.clone();
                Box::new(ParallelBackend::new(workers, move |_| r()))
            } else {
                replica()
            };
            match &cache {
                Some(c) => Box::new(MemoBackend::shared(inner, c.clone(), base + i as u32)),
                None => inner,
            }
        };
        runner.run(scenarios, &self.corpus, &self.tok, &self.registry, factory)
    }

    /// Run all four systems (Table III/IV composition) for one cloud model
    /// — one sweep over a shared workload.
    #[allow(clippy::type_complexity)]
    pub fn run_all_systems(
        &mut self,
        cloud_model: &str,
        rpm: f64,
        n: usize,
        seed: u64,
    ) -> Vec<(&'static str, Result<(RunMetrics, Vec<RequestTrace>), RunError>)> {
        let wl = Arc::new(self.workload(rpm, n, seed));
        let systems = baselines::all(cloud_model);
        let scenarios: Vec<SweepScenario> = systems
            .iter()
            .map(|(name, cfg)| SweepScenario::new(*name, cfg.clone(), wl.clone()))
            .collect();
        let results = self.run_sweep(&scenarios);
        systems.into_iter().map(|(name, _)| name).zip(results).collect()
    }
}

impl Drop for Env {
    fn drop(&mut self) {
        let _ = self.save_cache();
        let _ = self.save_calib();
    }
}

/// Cache-owner id of the `Env`'s own sequential backend; sweep scenarios
/// use ids starting at 1.
const ENV_SEQ_OWNER: u32 = 0;

/// Seed of the surrogate backend built by [`Env::load`]. Exported so
/// benches/tests constructing their own [`SurrogateBackend`] can share the
/// persistent cache with `Env`-driven runs — the seed shapes every
/// surrogate output, so it is part of the cache stamp.
pub const SURROGATE_SEED: u64 = 9;

/// Bump to invalidate every persistent generation cache (e.g. when backend
/// output semantics change without the artifacts changing).
pub const CACHE_STAMP_SALT: &str = "pice-gen-v1";

/// Auto-sized worker/sweep pools: one thread per available hardware
/// thread, capped at 8 — each [`ParallelBackend`] worker owns a full
/// backend replica (its own `LoadedModel` device buffers on the real
/// path), so the cap bounds resident memory. Determinism is unaffected by
/// the count: the index-ordered merge (workers) and submission-order
/// collection (sweep) keep output bit-identical at any size (PERF.md
/// §Worker-pool determinism rules).
pub fn auto_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
}

/// FNV-1a over length-delimited byte chunks -> printable stamp.
fn fnv_stamp(parts: &[&[u8]]) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for p in parts {
        eat(&(p.len() as u64).to_le_bytes());
        eat(p);
    }
    format!("{CACHE_STAMP_SALT}-{h:016x}")
}

/// Invalidation stamp for the real-backend cache: fingerprints the artifact
/// manifest, vocab, and every model's meta/weights/HLO files, so
/// regenerated artifacts orphan old cache sections. The manifest alone is
/// NOT enough — `aot.py` writes only shapes and model names there, so a
/// retrain leaves it byte-identical while changing every generation.
pub fn real_cache_stamp(art: &std::path::Path) -> String {
    // length + head/tail sample per file rather than a full hash: cheap at
    // bench startup, and any regeneration perturbs the sampled regions
    fn eat_sampled(content: &mut Vec<u8>, path: &std::path::Path) {
        use std::io::{Read, Seek, SeekFrom};
        let Ok(mut f) = std::fs::File::open(path) else { return };
        let len = f.metadata().map(|m| m.len()).unwrap_or(0);
        content.extend_from_slice(&len.to_le_bytes());
        let k = (len as usize).min(4096);
        let mut head = vec![0u8; k];
        if f.read_exact(&mut head).is_ok() {
            content.extend_from_slice(&head);
        }
        if len > 4096 {
            let mut tail = vec![0u8; 4096];
            if f.seek(SeekFrom::End(-4096)).is_ok() && f.read_exact(&mut tail).is_ok() {
                content.extend_from_slice(&tail);
            }
        }
    }
    let mut content: Vec<u8> = Vec::new();
    eat_sampled(&mut content, &art.join("manifest.json"));
    eat_sampled(&mut content, &art.join("vocab.json"));
    let mut model_dirs: Vec<std::path::PathBuf> = std::fs::read_dir(art.join("models"))
        .map(|rd| rd.filter_map(|e| e.ok().map(|e| e.path())).collect())
        .unwrap_or_default();
    model_dirs.sort();
    for dir in model_dirs {
        let name = dir.file_name().and_then(|n| n.to_str()).unwrap_or("");
        content.extend_from_slice(name.as_bytes());
        for f in [
            "meta.json",
            "weights.bin",
            "prefill.hlo.txt",
            "prefill_batch.hlo.txt",
            "decode.hlo.txt",
            "score.hlo.txt",
        ] {
            eat_sampled(&mut content, &dir.join(f));
        }
    }
    fnv_stamp(&[b"real", &content])
}

/// Invalidation stamp for the surrogate cache: fingerprints everything the
/// surrogate's outputs are a function of — the tokenizer size, the backend
/// `seed`, the registry's model names + MMLU values (they set each model's
/// corruption rate), and the full question/answer token content. Pass the
/// same registry and seed the [`SurrogateBackend`] was constructed with —
/// a mismatch would serve another backend's outputs as cache hits.
pub fn surrogate_cache_stamp(
    tok: &Tokenizer,
    corpus: &Corpus,
    registry: &Registry,
    seed: u64,
) -> String {
    let mut content: Vec<u8> = Vec::new();
    content.extend_from_slice(&(tok.vocab_size() as u64).to_le_bytes());
    content.extend_from_slice(&seed.to_le_bytes());
    for m in &registry.models {
        content.extend_from_slice(m.name.as_bytes());
        content.extend_from_slice(&m.mmlu.to_bits().to_le_bytes());
    }
    for q in &corpus.questions {
        content.extend_from_slice(&(q.id as u64).to_le_bytes());
        for &t in &q.question {
            content.extend_from_slice(&t.to_le_bytes());
        }
        for sent in &q.sentences {
            for &t in &sent.full {
                content.extend_from_slice(&t.to_le_bytes());
            }
            for &t in &sent.sketch {
                content.extend_from_slice(&t.to_le_bytes());
            }
        }
    }
    fnv_stamp(&[b"surrogate", &content])
}

/// Bench sizing from the environment: `PICE_BENCH_N` (requests per scenario,
/// default 60), `PICE_BENCH_SMOKE=1` (tiny smoke sizing for CI).
pub fn bench_n() -> usize {
    if std::env::var("PICE_BENCH_SMOKE").as_deref() == Ok("1") {
        return 12;
    }
    std::env::var("PICE_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(60)
}
