//! Discrete-event simulation core.
//!
//! The paper's testbed (4x Jetson AGX Orin + 4xA100 cloud) is replaced by a
//! simulated cluster (DESIGN.md §2). Text generation is *real* (PJRT picoLM
//! decode); the testbed clock is *virtual*: every compute/transfer advances
//! simulated time according to the calibrated device/network models, so
//! throughput/latency experiments reproduce the paper's scale.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated timestamp in seconds.
pub type SimTime = f64;

struct Entry<E> {
    time: SimTime,
    seq: u64, // FIFO tie-break for equal timestamps
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with a monotonically advancing clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute simulated time `at` (clamped to now).
    pub fn schedule(&mut self, at: SimTime, event: E) {
        let t = if at < self.now { self.now } else { at };
        self.heap.push(Entry { time: t, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a delay from the current clock.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        let now = self.now;
        self.schedule(now + delay.max(0.0), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now, "time went backwards");
            self.now = e.time;
            (e.time, e.event)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_first() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_monotone() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.pop();
        assert_eq!(q.now(), 5.0);
        // scheduling in the past clamps to now
        q.schedule(1.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }

    #[test]
    fn schedule_in_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, ());
        q.pop();
        q.schedule_in(3.0, ());
        assert_eq!(q.pop().unwrap().0, 5.0);
    }
}
