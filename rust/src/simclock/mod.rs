//! Discrete-event simulation core.
//!
//! The paper's testbed (4x Jetson AGX Orin + 4xA100 cloud) is replaced by a
//! simulated cluster (DESIGN.md §2). Text generation is *real* (PJRT picoLM
//! decode); the testbed clock is *virtual*: every compute/transfer advances
//! simulated time according to the calibrated device/network models, so
//! throughput/latency experiments reproduce the paper's scale.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated timestamp in seconds.
pub type SimTime = f64;

/// Priority class of plain [`EventQueue::schedule`] calls.
pub const DEFAULT_CLASS: u8 = 1;
/// Highest-priority class: pops before every same-time default-class event.
pub const FIRST_CLASS: u8 = 0;

struct Entry<E> {
    time: SimTime,
    /// priority class at equal timestamps: lower pops first. Lets external
    /// arrivals injected mid-run (`Engine::submit`) order ahead of internal
    /// events at the same instant, exactly as if they had been scheduled
    /// up-front — the invariant the open-loop serving API's bit-identical
    /// guarantee rests on.
    class: u8,
    seq: u64, // FIFO tie-break for equal (time, class)
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.class == other.class && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.class.cmp(&self.class))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Earliest-first event queue with a monotonically advancing clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    now: SimTime,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute simulated time `at` (clamped to now).
    pub fn schedule(&mut self, at: SimTime, event: E) {
        self.schedule_class(at, DEFAULT_CLASS, event);
    }

    /// Schedule with an explicit same-timestamp priority class (lower pops
    /// first; ties within a class stay FIFO by insertion).
    pub fn schedule_class(&mut self, at: SimTime, class: u8, event: E) {
        let t = if at < self.now { self.now } else { at };
        self.heap.push(Entry { time: t, class, seq: self.seq, event });
        self.seq += 1;
    }

    /// Timestamp of the next event without popping it.
    pub fn next_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    /// Schedule `event` after a delay from the current clock.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        debug_assert!(delay >= 0.0, "negative delay {delay}");
        let now = self.now;
        self.schedule(now + delay.max(0.0), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now, "time went backwards");
            self.now = e.time;
            (e.time, e.event)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_first() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_monotone() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.pop();
        assert_eq!(q.now(), 5.0);
        // scheduling in the past clamps to now
        q.schedule(1.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 5.0);
    }

    #[test]
    fn first_class_pops_before_default_at_equal_time() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "default-early");
        q.schedule_class(1.0, FIRST_CLASS, "arrival");
        q.schedule(1.0, "default-late");
        assert_eq!(q.pop().unwrap().1, "arrival");
        assert_eq!(q.pop().unwrap().1, "default-early");
        assert_eq!(q.pop().unwrap().1, "default-late");
        // classes only reorder ties; time still dominates
        q.schedule(3.0, "t3-first");
        q.schedule_class(5.0, FIRST_CLASS, "t5-arrival");
        assert_eq!(q.pop().unwrap().1, "t3-first");
        assert_eq!(q.pop().unwrap().1, "t5-arrival");
    }

    #[test]
    fn next_time_peeks_without_popping() {
        let mut q = EventQueue::new();
        assert!(q.next_time().is_none());
        q.schedule(2.0, ());
        q.schedule(1.0, ());
        assert_eq!(q.next_time(), Some(1.0));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn schedule_in_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, ());
        q.pop();
        q.schedule_in(3.0, ());
        assert_eq!(q.pop().unwrap().0, 5.0);
    }
}
