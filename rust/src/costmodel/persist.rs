//! Cross-process calibration persistence: warm starts for [`Calibrated`].
//!
//! Same scheme as the generation-memo snapshot (`sweep/cache.rs`): one
//! versioned JSON file (default next to the memo snapshot, via
//! `PICE_CALIB_PATH`), sectioned by the artifact *stamp* — the FNV
//! fingerprint of corpus + registry + backend identity that already
//! invalidates the memo cache. A calibration learned against one world is
//! meaningless in another, so a stamp mismatch is a cold start, never an
//! error; other stamps' sections are retained verbatim (bounded) so
//! differently-stamped runs can share one file. Within a stamp, entries are
//! keyed by [`calib_key`] — the engine-shape identity (cloud model, edge
//! count, policy) — so e.g. a 4-edge PICE run never warms a 2-edge one.
//!
//! f64 state is stored as hex bit patterns ([`u64`] hex strings, like the
//! memo store's seeds): a reloaded [`CalibState`] is bit-identical to the
//! saved one, which is what makes the warm-start round-trip test exact.
//!
//! [`Calibrated`]: super::Calibrated

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::CalibState;
use crate::util::json::{self, Json};

/// On-disk calibration format version; bump when [`CalibState`] changes.
pub const CALIB_VERSION: usize = 1;

/// Foreign-stamp sections retained on save — bounds file growth when many
/// differently-stamped runs share one path (mirrors the memo snapshot).
const FOREIGN_STAMP_LIMIT: usize = 8;

/// The engine-shape identity a calibration is valid for. Policy and
/// static-mode matter because they change which decisions feed the model;
/// edge count changes the cost coefficient's meaning.
pub fn calib_key(cloud_model: &str, n_edges: usize, policy: &str, static_mode: bool) -> String {
    format!(
        "{cloud_model}/e{n_edges}/{policy}{}",
        if static_mode { "/static" } else { "" }
    )
}

/// One process-wide binding of calibration state to a snapshot file.
/// Load once at startup ([`CalibStore::load`]), read warm states via
/// [`CalibStore::get`], deposit end-of-run states via [`CalibStore::put`],
/// save once at exit ([`CalibStore::save`]).
pub struct CalibStore {
    path: PathBuf,
    stamp: String,
    /// this stamp's section: calib_key -> state
    entries: BTreeMap<String, CalibState>,
    /// other stamps' sections, re-emitted verbatim on save
    foreign: Vec<(String, Json)>,
    restored: usize,
    dirty: bool,
}

impl CalibStore {
    /// Bind `path` for `stamp`, restoring that stamp's section of any
    /// matching-version file. Missing, unreadable, corrupt, or
    /// differently-stamped files all mean a cold start — never an error.
    pub fn load(path: impl Into<PathBuf>, stamp: &str) -> CalibStore {
        let path = path.into();
        let mut entries = BTreeMap::new();
        let mut foreign: Vec<(String, Json)> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(snap) = Json::parse(&text) {
                if snap.get("version").and_then(Json::as_usize) == Some(CALIB_VERSION) {
                    if let Some(Json::Obj(stamps)) = snap.get("stamps") {
                        for (st, section) in stamps {
                            if st == stamp {
                                if let Json::Obj(m) = section {
                                    for (key, sj) in m {
                                        if let Some(state) = state_from_json(sj) {
                                            entries.insert(key.clone(), state);
                                        }
                                    }
                                }
                            } else if foreign.len() < FOREIGN_STAMP_LIMIT {
                                foreign.push((st.clone(), section.clone()));
                            }
                        }
                    }
                }
            }
        }
        let restored = entries.len();
        CalibStore { path, stamp: stamp.to_string(), entries, foreign, restored, dirty: false }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// States restored from disk at load (0 on a cold start).
    pub fn restored_entries(&self) -> usize {
        self.restored
    }

    /// Warm state for an engine shape, if this stamp has one.
    pub fn get(&self, key: &str) -> Option<CalibState> {
        self.entries.get(key).cloned()
    }

    /// Deposit an end-of-run state. Non-finite states are refused (they
    /// could only poison later runs); depositing marks the store dirty.
    pub fn put(&mut self, key: &str, state: CalibState) {
        if !state.is_finite() {
            return;
        }
        self.entries.insert(key.to_string(), state);
        self.dirty = true;
    }

    /// Anything new to write since load / the last save?
    pub fn dirty(&self) -> bool {
        self.dirty
    }

    /// Write the file back: this stamp's section from `entries`, other
    /// stamps verbatim. Temp-file + rename, so a crashed process never
    /// leaves a torn file.
    pub fn save(&mut self) -> Result<(), String> {
        let mut section = BTreeMap::new();
        for (key, state) in &self.entries {
            section.insert(key.clone(), state_json(state));
        }
        let mut stamps = BTreeMap::new();
        for (st, sec) in &self.foreign {
            stamps.insert(st.clone(), sec.clone());
        }
        stamps.insert(self.stamp.clone(), Json::Obj(section));
        let snap = json::obj(vec![
            ("version", json::num(CALIB_VERSION as f64)),
            ("stamps", Json::Obj(stamps)),
        ]);
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                let _ = std::fs::create_dir_all(dir);
            }
        }
        let tmp = self.path.with_extension(format!("tmp{}", std::process::id()));
        std::fs::write(&tmp, snap.to_string())
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| format!("rename to {}: {e}", self.path.display()))?;
        self.dirty = false;
        Ok(())
    }
}

fn f64_hex(v: f64) -> Json {
    Json::Str(format!("{:016x}", v.to_bits()))
}

fn parse_f64_hex(j: &Json) -> Option<f64> {
    let v = f64::from_bits(u64::from_str_radix(j.as_str()?, 16).ok()?);
    v.is_finite().then_some(v)
}

fn u64_json(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn parse_u64(j: &Json) -> Option<u64> {
    u64::from_str_radix(j.as_str()?, 16).ok()
}

fn state_json(st: &CalibState) -> Json {
    json::obj(vec![
        ("n", f64_hex(st.n)),
        ("sx", f64_hex(st.sx)),
        ("sy", f64_hex(st.sy)),
        ("sxx", f64_hex(st.sxx)),
        ("sxy", f64_hex(st.sxy)),
        ("edge_corr", f64_hex(st.edge_corr)),
        ("transfer_corr", f64_hex(st.transfer_corr)),
        ("parallelism", f64_hex(st.parallelism)),
        ("resid_s", f64_hex(st.resid_s)),
        ("cloud_samples", u64_json(st.cloud_samples)),
        ("edge_samples", u64_json(st.edge_samples)),
        ("transfer_samples", u64_json(st.transfer_samples)),
    ])
}

fn state_from_json(j: &Json) -> Option<CalibState> {
    Some(CalibState {
        n: parse_f64_hex(j.get("n")?)?,
        sx: parse_f64_hex(j.get("sx")?)?,
        sy: parse_f64_hex(j.get("sy")?)?,
        sxx: parse_f64_hex(j.get("sxx")?)?,
        sxy: parse_f64_hex(j.get("sxy")?)?,
        edge_corr: parse_f64_hex(j.get("edge_corr")?)?,
        transfer_corr: parse_f64_hex(j.get("transfer_corr")?)?,
        parallelism: parse_f64_hex(j.get("parallelism")?)?,
        resid_s: parse_f64_hex(j.get("resid_s")?)?,
        cloud_samples: parse_u64(j.get("cloud_samples")?)?,
        edge_samples: parse_u64(j.get("edge_samples")?)?,
        transfer_samples: parse_u64(j.get("transfer_samples")?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state(tag: f64) -> CalibState {
        CalibState {
            n: 40.0 + tag,
            sx: 1.5e4 + tag,
            sy: 88.25 + tag,
            sxx: 6.1e6 + tag,
            sxy: 3.3e4 + tag,
            edge_corr: 1.37 + 0.01 * tag,
            transfer_corr: 0.81,
            parallelism: 2.625,
            resid_s: 0.0625,
            cloud_samples: 40,
            edge_samples: 17,
            transfer_samples: 9,
        }
    }

    fn tmp_path(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("pice_calib_{name}_{}.json", std::process::id()))
    }

    #[test]
    fn state_json_round_trip_bit_exact() {
        // include an awkward irrational-ish value: hex bit patterns make
        // the round trip exact regardless of decimal printability
        let mut st = sample_state(0.0);
        st.sxy = std::f64::consts::PI * 1e4;
        let j = state_json(&st);
        let re = state_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(re, st);
        assert_eq!(re.sxy.to_bits(), st.sxy.to_bits());
    }

    #[test]
    fn store_round_trip_and_dirty_tracking() {
        let path = tmp_path("rt");
        let _ = std::fs::remove_file(&path);
        let mut store = CalibStore::load(&path, "stamp-a");
        assert_eq!(store.restored_entries(), 0);
        assert!(!store.dirty());
        let key = calib_key("llama70b-sim", 4, "pice", false);
        store.put(&key, sample_state(1.0));
        assert!(store.dirty());
        store.save().unwrap();
        assert!(!store.dirty());

        let store2 = CalibStore::load(&path, "stamp-a");
        assert_eq!(store2.restored_entries(), 1);
        assert_eq!(store2.get(&key).unwrap(), sample_state(1.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stale_stamp_cold_starts_but_is_retained() {
        let path = tmp_path("stale");
        let _ = std::fs::remove_file(&path);
        let key = calib_key("llama70b-sim", 4, "pice", false);
        let mut a = CalibStore::load(&path, "stamp-a");
        a.put(&key, sample_state(1.0));
        a.save().unwrap();

        // a different stamp sees a cold start...
        let mut b = CalibStore::load(&path, "stamp-b");
        assert_eq!(b.restored_entries(), 0);
        assert!(b.get(&key).is_none());
        b.put(&key, sample_state(2.0));
        b.save().unwrap();

        // ...but stamp-a's section survived stamp-b's save
        let a2 = CalibStore::load(&path, "stamp-a");
        assert_eq!(a2.get(&key).unwrap(), sample_state(1.0));
        let b2 = CalibStore::load(&path, "stamp-b");
        assert_eq!(b2.get(&key).unwrap(), sample_state(2.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_or_wrong_version_is_a_cold_start() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "{not json").unwrap();
        assert_eq!(CalibStore::load(&path, "s").restored_entries(), 0);
        std::fs::write(&path, r#"{"version": 999, "stamps": {}}"#).unwrap();
        assert_eq!(CalibStore::load(&path, "s").restored_entries(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn non_finite_state_is_refused() {
        let path = tmp_path("nonfinite");
        let _ = std::fs::remove_file(&path);
        let mut store = CalibStore::load(&path, "s");
        let mut bad = sample_state(0.0);
        bad.edge_corr = f64::NAN;
        store.put("k", bad);
        assert!(!store.dirty());
        assert!(store.get("k").is_none());
    }

    #[test]
    fn calib_key_shapes_are_distinct() {
        let a = calib_key("m", 4, "pice", false);
        let b = calib_key("m", 2, "pice", false);
        let c = calib_key("m", 4, "pice", true);
        let d = calib_key("m2", 4, "pice", false);
        let keys = [&a, &b, &c, &d];
        for i in 0..keys.len() {
            for j in (i + 1)..keys.len() {
                assert_ne!(keys[i], keys[j]);
            }
        }
    }
}
