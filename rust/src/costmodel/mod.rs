//! The cost-model layer: every quantity Eq. 2 consumes, behind one trait.
//!
//! Before this layer, Eq. 2's inputs were scattered: static affine
//! [`LatencyFit`]s in `profiler/`, an ad-hoc `ewma_parallelism` field in the
//! engine core, a mostly-unwired `RuntimeMonitor`, and backlog estimation
//! duplicated between `Engine::backlog_estimate_s` and the fleet router.
//! [`CostModel`] owns all of it — cloud-latency, edge-rate, transfer and
//! backlog estimation plus the achieved-parallelism hint — and the engine
//! threads ONE instance through scheduling, admission, fleet placement and
//! serve deadline checks.
//!
//! Two implementations:
//!
//! * [`StaticFit`] — the offline profile, verbatim. The default. Every
//!   expression reproduces the pre-refactor inline arithmetic **bit for
//!   bit**: corrections are the multiplicative identity (`x * 1.0 == x`
//!   exactly in IEEE 754), the parallelism EWMA uses the same
//!   `(1 - α)·p + α·lanes` update (α = 0.2 ⇒ `1.0 - 0.2 == 0.8` exactly),
//!   and every observation hook is a no-op.
//! * [`Calibrated`] — closes ROADMAP item 2's loop: a decayed online OLS
//!   re-fit of the cloud latency line fed by observed cloud service times,
//!   EWMA ratio corrections for edge service rate and WAN transfer drift,
//!   and the same parallelism EWMA. All observations arrive from the
//!   engine's *deterministic event stream* (cloud admissions, edge pulls,
//!   sketch transfers), so calibrated traces stay bit-identical across
//!   sweep thread counts and open- vs closed-loop driving.
//!
//! Calibration state round-trips through [`persist::CalibStore`]
//! (`PICE_CALIB_PATH`, versioned JSON, same stamp/invalidation scheme as
//! the memo snapshot) so later runs start warm — see `CalibMode::Warm`.

pub mod persist;

pub use persist::{calib_key, CalibStore, CALIB_VERSION};

use crate::coordinator::dispatch::MultiListQueue;
use crate::network::TransferModel;
use crate::profiler::LatencyFit;
use crate::simclock::SimTime;

/// How the engine's cost model behaves over a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibMode {
    /// offline fits only — the pre-refactor behavior, bit-identical
    Off,
    /// learn online from this run's own event stream, starting cold
    On,
    /// learn online, seeded from persisted state when available
    Warm,
}

/// Calibration knobs (the former hardcoded EWMA constants, now validated
/// configuration). `Default` reproduces the historical values exactly:
/// parallelism EWMA `0.8/0.2`, rate EWMA α = 0.2 with ratio clamp
/// `[0.25, 4.0]`.
#[derive(Clone, Debug)]
pub struct CalibCfg {
    pub mode: CalibMode,
    /// EWMA weight of a new achieved-parallelism sample (0 freezes the
    /// hint at its conservative p = 1 initial value)
    pub parallel_alpha: f64,
    /// EWMA weight of a new observed/predicted rate ratio (edge + transfer
    /// corrections; 0 freezes both corrections at 1.0)
    pub rate_alpha: f64,
    /// observed/predicted ratios are clamped to `[clamp_lo, clamp_hi]`
    /// before entering the EWMA, and the re-fitted cloud slope is clamped
    /// to `base.b * [clamp_lo, clamp_hi]` — one outlier can't capsize the
    /// model
    pub clamp_lo: f64,
    pub clamp_hi: f64,
    /// per-sample decay of the online-regression accumulators (1.0 = no
    /// forgetting; lower tracks drift faster)
    pub decay: f64,
    /// cloud samples required before the online re-fit replaces the
    /// offline line
    pub min_samples: usize,
    /// drift age-out threshold: a *warm-loaded* state is graded against
    /// every live cloud observation, and a sample counts as off-world when
    /// `max(obs, pred) / min(obs, pred)` exceeds this ratio (symmetric —
    /// a stale-fast and a stale-slow line age out alike)
    pub drift_ratio: f64,
    /// consecutive off-world samples before the warm state is discarded
    /// and the model re-learns cold
    pub drift_samples: usize,
    /// persisted state to seed from under `CalibMode::Warm` (ignored
    /// otherwise)
    pub warm: Option<CalibState>,
}

impl Default for CalibCfg {
    fn default() -> Self {
        CalibCfg {
            mode: CalibMode::Off,
            parallel_alpha: 0.2,
            rate_alpha: 0.2,
            clamp_lo: 0.25,
            clamp_hi: 4.0,
            decay: 0.995,
            min_samples: 16,
            drift_ratio: 3.0,
            drift_samples: 8,
            warm: None,
        }
    }
}

impl CalibCfg {
    /// Reject out-of-domain knobs with a message naming the offender (the
    /// CLI surfaces this verbatim).
    pub fn validate(&self) -> Result<(), String> {
        let unit = |name: &str, v: f64| -> Result<(), String> {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{name} must be in [0, 1], got {v}"));
            }
            Ok(())
        };
        unit("calib parallel_alpha", self.parallel_alpha)?;
        unit("calib rate_alpha", self.rate_alpha)?;
        if !(self.clamp_lo.is_finite() && self.clamp_hi.is_finite())
            || self.clamp_lo <= 0.0
            || self.clamp_lo > self.clamp_hi
        {
            return Err(format!(
                "calib clamp must satisfy 0 < lo <= hi, got [{}, {}]",
                self.clamp_lo, self.clamp_hi
            ));
        }
        if !self.decay.is_finite() || self.decay <= 0.0 || self.decay > 1.0 {
            return Err(format!("calib decay must be in (0, 1], got {}", self.decay));
        }
        if self.min_samples < 2 {
            return Err(format!(
                "calib min_samples must be >= 2 (a line needs two points), got {}",
                self.min_samples
            ));
        }
        if !self.drift_ratio.is_finite() || self.drift_ratio <= 1.0 {
            return Err(format!("calib drift_ratio must be > 1, got {}", self.drift_ratio));
        }
        if self.drift_samples == 0 {
            return Err("calib drift_samples must be >= 1".into());
        }
        Ok(())
    }

    /// Overlay `PICE_CALIB_*` environment knobs onto `self`. Strict: a set
    /// but unparsable value is an error, not a silent default —
    /// `PICE_CALIB_PARALLEL_ALPHA`, `PICE_CALIB_RATE_ALPHA`,
    /// `PICE_CALIB_CLAMP` ("lo,hi"), `PICE_CALIB_DECAY`,
    /// `PICE_CALIB_MIN_SAMPLES`.
    pub fn overlay_env(mut self) -> Result<CalibCfg, String> {
        fn f64_knob(key: &str) -> Result<Option<f64>, String> {
            match std::env::var(key) {
                Ok(v) => v
                    .parse::<f64>()
                    .map(Some)
                    .map_err(|_| format!("{key}={v} is not a number")),
                Err(_) => Ok(None),
            }
        }
        if let Some(v) = f64_knob("PICE_CALIB_PARALLEL_ALPHA")? {
            self.parallel_alpha = v;
        }
        if let Some(v) = f64_knob("PICE_CALIB_RATE_ALPHA")? {
            self.rate_alpha = v;
        }
        if let Ok(v) = std::env::var("PICE_CALIB_CLAMP") {
            let parts: Vec<&str> = v.split(',').collect();
            let parsed = (parts.len() == 2)
                .then(|| {
                    Some((
                        parts[0].trim().parse::<f64>().ok()?,
                        parts[1].trim().parse::<f64>().ok()?,
                    ))
                })
                .flatten();
            match parsed {
                Some((lo, hi)) => {
                    self.clamp_lo = lo;
                    self.clamp_hi = hi;
                }
                None => return Err(format!("PICE_CALIB_CLAMP={v} is not \"lo,hi\"")),
            }
        }
        if let Some(v) = f64_knob("PICE_CALIB_DECAY")? {
            self.decay = v;
        }
        if let Ok(v) = std::env::var("PICE_CALIB_MIN_SAMPLES") {
            self.min_samples = v
                .parse::<usize>()
                .map_err(|_| format!("PICE_CALIB_MIN_SAMPLES={v} is not an integer"))?;
        }
        self.validate()?;
        Ok(self)
    }
}

/// One scheduling decision's worth of model outputs — what
/// [`crate::coordinator::scheduler::CloudScheduler`] consumes next to the
/// per-query [`crate::coordinator::scheduler::SchedInput`] descriptor.
#[derive(Clone, Copy, Debug)]
pub struct Estimates {
    /// cloud latency line f(l) (offline fit, or the online re-fit)
    pub f_cloud: LatencyFit,
    /// cost coefficient c for the current best SLM/edge pair (edge-rate
    /// corrected under calibration)
    pub cost_coeff: f64,
    /// Δ: transfer model of the sketch hop (WAN-drift corrected under
    /// calibration)
    pub transfer: TransferModel,
    /// Eq. 2 backlog: c · Σ_j f(l_j) over queued expansion jobs
    pub backlog_s: SimTime,
    /// achieved edge expansion parallelism (EWMA; 1.0 = the paper's
    /// conservative p = 1 default)
    pub parallel_hint: f64,
}

/// Live calibration snapshot for the metrics dump / CLI summary line.
#[derive(Clone, Copy, Debug)]
pub struct CalibSummary {
    pub learning: bool,
    /// offline baseline the model started from
    pub base_f_cloud: LatencyFit,
    /// current effective cloud line
    pub f_cloud: LatencyFit,
    pub edge_corr: f64,
    pub transfer_corr: f64,
    pub parallelism: f64,
    /// EWMA of |observed - predicted| cloud service time, seconds
    pub resid_s: f64,
    pub cloud_samples: u64,
    pub edge_samples: u64,
    pub transfer_samples: u64,
}

impl std::fmt::Display for CalibSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if !self.learning {
            return write!(
                f,
                "calibration off: f(l) = {:.4} + {:.6}·l (offline), p_hint {:.2}",
                self.f_cloud.a, self.f_cloud.b, self.parallelism
            );
        }
        write!(
            f,
            "calibration on: f(l) = {:.4} + {:.6}·l (offline {:.4} + {:.6}·l), \
             edge_corr {:.3}, transfer_corr {:.3}, p_hint {:.2}, resid {:.3}s, \
             samples cloud/edge/transfer {}/{}/{}",
            self.f_cloud.a,
            self.f_cloud.b,
            self.base_f_cloud.a,
            self.base_f_cloud.b,
            self.edge_corr,
            self.transfer_corr,
            self.parallelism,
            self.resid_s,
            self.cloud_samples,
            self.edge_samples,
            self.transfer_samples
        )
    }
}

/// Persistable calibration state: the decayed-OLS accumulators plus the
/// EWMA corrections — everything a warm start needs to resume exactly
/// where a donor run stopped. All fields finite (enforced at save).
#[derive(Clone, Debug, PartialEq)]
pub struct CalibState {
    pub n: f64,
    pub sx: f64,
    pub sy: f64,
    pub sxx: f64,
    pub sxy: f64,
    pub edge_corr: f64,
    pub transfer_corr: f64,
    pub parallelism: f64,
    pub resid_s: f64,
    pub cloud_samples: u64,
    pub edge_samples: u64,
    pub transfer_samples: u64,
}

impl CalibState {
    pub fn is_finite(&self) -> bool {
        [
            self.n,
            self.sx,
            self.sy,
            self.sxx,
            self.sxy,
            self.edge_corr,
            self.transfer_corr,
            self.parallelism,
            self.resid_s,
        ]
        .iter()
        .all(|x| x.is_finite())
    }
}

/// Everything Eq. 2 asks about the world. One instance per engine, owned by
/// the engine core; observations arrive only from that engine's own event
/// handlers, so the model is a pure function of the deterministic event
/// stream (the determinism contract all serving tests enforce).
pub trait CostModel: std::fmt::Debug + Send {
    /// Cloud latency line f(l).
    fn f_cloud(&self) -> LatencyFit;

    /// Cost coefficient c (edge-vs-cloud per-token ratio for the best
    /// SLM/edge pair), edge-rate corrected under calibration.
    fn cost_coeff(&self) -> f64;

    /// Transfer model for the sketch hop, given the live link's model.
    /// [`StaticFit`] returns `live` untouched.
    fn transfer(&self, live: TransferModel) -> TransferModel;

    /// Multiplicative correction on a raw transfer-seconds estimate —
    /// exactly 1.0 for [`StaticFit`], so `scale * raw == raw` bit-exact.
    fn transfer_scale(&self) -> f64 {
        1.0
    }

    /// Achieved edge expansion parallelism hint p (EWMA; starts at the
    /// conservative 1.0).
    fn parallel_hint(&self) -> f64;

    /// Eq. 2 backlog term: c · Σ_j f(l_j) over the queued expansion jobs.
    fn backlog_s(&self, q: &MultiListQueue) -> SimTime {
        self.cost_coeff() * q.backlog_cost(&self.f_cloud())
    }

    /// The admission-gate estimate (`Engine::backlog_estimate_s`): queued
    /// Eq. 2 backlog plus one (corrected) sketch transfer, `raw_transfer_s`
    /// being the live link's uncorrected transfer seconds.
    fn admission_backlog_s(&self, q: &MultiListQueue, raw_transfer_s: SimTime) -> SimTime {
        self.backlog_s(q) + self.transfer_scale() * raw_transfer_s
    }

    /// All Eq. 2 inputs for one decision, in one call.
    fn estimates(&self, live: TransferModel, q: &MultiListQueue) -> Estimates {
        Estimates {
            f_cloud: self.f_cloud(),
            cost_coeff: self.cost_coeff(),
            transfer: self.transfer(live),
            backlog_s: self.backlog_s(q),
            parallel_hint: self.parallel_hint(),
        }
    }

    /// True when observations actually update state beyond the parallelism
    /// EWMA — the engine gates its observation bookkeeping on this so the
    /// static path stays zero-cost.
    fn learning(&self) -> bool {
        false
    }

    /// Mean lanes-per-job of a completed batch plan (every pull reports).
    fn observe_parallelism(&mut self, mean_lanes: f64);

    /// A cloud generation of `sim_tokens` took `observed_s` at the live
    /// batch size.
    fn observe_cloud(&mut self, _sim_tokens: usize, _observed_s: SimTime) {}

    /// An edge pull predicted `predicted_s` (c·f(l)/p at decision time) and
    /// took `observed_s` wall.
    fn observe_edge(&mut self, _predicted_s: SimTime, _observed_s: SimTime) {}

    /// A sketch transfer predicted `predicted_s` (decision-time transfer
    /// model at the actual sketch length) and took `observed_s`.
    fn observe_transfer(&mut self, _predicted_s: SimTime, _observed_s: SimTime) {}

    /// Snapshot for the metrics dump.
    fn summary(&self) -> CalibSummary;

    /// Persistable state (None for [`StaticFit`] — nothing to warm-start).
    fn state(&self) -> Option<CalibState> {
        None
    }
}

/// Build the model an [`crate::coordinator::EngineCfg`] asks for from the
/// offline profile's outputs. The caller validates `calib` first.
pub fn build(calib: &CalibCfg, base: LatencyFit, cost_coeff: f64) -> Box<dyn CostModel> {
    match calib.mode {
        CalibMode::Off => Box::new(StaticFit::new(base, cost_coeff, calib.parallel_alpha)),
        CalibMode::On | CalibMode::Warm => {
            let mut m = Calibrated::new(base, cost_coeff, calib.clone());
            if calib.mode == CalibMode::Warm {
                if let Some(st) = &calib.warm {
                    m.load_state(st);
                }
            }
            Box::new(m)
        }
    }
}

// ---------------------------------------------------------------------------
// StaticFit
// ---------------------------------------------------------------------------

/// The offline profile, verbatim — today's behavior, bit-identical. The
/// only mutable state is the achieved-parallelism EWMA the pre-refactor
/// engine already tracked (`0.8·p + 0.2·lanes`, now α-configurable with the
/// default reproducing those constants exactly: `1.0 - 0.2 == 0.8` in f64).
#[derive(Clone, Debug)]
pub struct StaticFit {
    f: LatencyFit,
    c: f64,
    parallel_alpha: f64,
    parallelism: f64,
}

impl StaticFit {
    pub fn new(base: LatencyFit, cost_coeff: f64, parallel_alpha: f64) -> Self {
        StaticFit { f: base, c: cost_coeff, parallel_alpha, parallelism: 1.0 }
    }
}

impl CostModel for StaticFit {
    fn f_cloud(&self) -> LatencyFit {
        self.f
    }

    fn cost_coeff(&self) -> f64 {
        self.c
    }

    fn transfer(&self, live: TransferModel) -> TransferModel {
        live
    }

    fn parallel_hint(&self) -> f64 {
        self.parallelism
    }

    fn observe_parallelism(&mut self, mean_lanes: f64) {
        self.parallelism =
            (1.0 - self.parallel_alpha) * self.parallelism + self.parallel_alpha * mean_lanes;
    }

    fn summary(&self) -> CalibSummary {
        CalibSummary {
            learning: false,
            base_f_cloud: self.f,
            f_cloud: self.f,
            edge_corr: 1.0,
            transfer_corr: 1.0,
            parallelism: self.parallelism,
            resid_s: 0.0,
            cloud_samples: 0,
            edge_samples: 0,
            transfer_samples: 0,
        }
    }
}

// ---------------------------------------------------------------------------
// Calibrated
// ---------------------------------------------------------------------------

/// Online-calibrated model: a decayed OLS re-fit of the cloud line over
/// observed (response length, service time) pairs, EWMA observed/predicted
/// ratio corrections for the edge rate (folded into c) and WAN transfer,
/// and the parallelism EWMA. With `rate_alpha = 0` and `min_samples`
/// unreachable every correction stays at its identity and the model
/// decides bit-identically to [`StaticFit`] (the null-calibration test).
#[derive(Clone, Debug)]
pub struct Calibrated {
    base: LatencyFit,
    base_c: f64,
    cfg: CalibCfg,
    st: CalibState,
    /// current effective fit — recomputed on each cloud observation, read
    /// on the (much hotter) estimate path
    fit: LatencyFit,
    /// state arrived via [`Calibrated::load_state`] — arms the drift
    /// age-out (a cold-learned state is never aged out: it IS this world)
    warm_loaded: bool,
    /// consecutive cloud observations off-world by more than
    /// `cfg.drift_ratio` (see `observe_cloud`)
    drift_streak: usize,
}

impl Calibrated {
    pub fn new(base: LatencyFit, cost_coeff: f64, mut cfg: CalibCfg) -> Self {
        cfg.warm = None; // state arrives via load_state, not retained config
        Calibrated {
            base,
            base_c: cost_coeff,
            cfg,
            st: CalibState {
                n: 0.0,
                sx: 0.0,
                sy: 0.0,
                sxx: 0.0,
                sxy: 0.0,
                edge_corr: 1.0,
                transfer_corr: 1.0,
                parallelism: 1.0,
                resid_s: 0.0,
                cloud_samples: 0,
                edge_samples: 0,
                transfer_samples: 0,
            },
            fit: base,
            warm_loaded: false,
            drift_streak: 0,
        }
    }

    /// Seed from persisted state (ignores non-finite snapshots defensively;
    /// the store also refuses to save them). Arms the drift age-out: a
    /// warm state whose predictions stop matching the live world is
    /// discarded (see `observe_cloud`).
    pub fn load_state(&mut self, st: &CalibState) {
        if st.is_finite() {
            self.st = st.clone();
            self.warm_loaded = true;
            self.drift_streak = 0;
            self.refit();
        }
    }

    /// Discard all learned state and restart cold (drift age-out): the
    /// accumulators zero, every correction returns to identity, and the
    /// effective line falls back to the offline fit until `min_samples`
    /// fresh observations arrive.
    fn reset_cold(&mut self) {
        let fresh = Calibrated::new(self.base, self.base_c, self.cfg.clone());
        self.st = fresh.st;
        self.fit = self.base;
        self.warm_loaded = false;
        self.drift_streak = 0;
    }

    /// Recompute the effective line from the accumulators: activate only
    /// past `min_samples`, clamp the slope to `base.b * [clamp_lo,
    /// clamp_hi]`, floor the intercept at 0, and fall back to the offline
    /// line on a degenerate system.
    fn refit(&mut self) {
        if self.st.cloud_samples < self.cfg.min_samples as u64 {
            self.fit = self.base;
            return;
        }
        let (n, sx, sy, sxx, sxy) = (self.st.n, self.st.sx, self.st.sy, self.st.sxx, self.st.sxy);
        let det = n * sxx - sx * sx;
        if !(det.is_finite() && det.abs() > 1e-9 * sxx.max(1.0)) {
            self.fit = self.base;
            return;
        }
        let b = (n * sxy - sx * sy) / det;
        let a = (sy - b * sx) / n;
        if !(a.is_finite() && b.is_finite()) {
            self.fit = self.base;
            return;
        }
        let b = b.clamp(self.base.b * self.cfg.clamp_lo, self.base.b * self.cfg.clamp_hi);
        self.fit = LatencyFit { a: a.max(0.0), b };
    }

    fn ewma_ratio(&self, current: f64, observed: f64, predicted: f64) -> Option<f64> {
        if !(observed.is_finite() && predicted.is_finite()) || predicted <= 0.0 {
            return None;
        }
        let ratio = (observed / predicted).clamp(self.cfg.clamp_lo, self.cfg.clamp_hi);
        Some((1.0 - self.cfg.rate_alpha) * current + self.cfg.rate_alpha * ratio)
    }
}

impl CostModel for Calibrated {
    fn f_cloud(&self) -> LatencyFit {
        self.fit
    }

    fn cost_coeff(&self) -> f64 {
        self.base_c * self.st.edge_corr
    }

    fn transfer(&self, live: TransferModel) -> TransferModel {
        TransferModel {
            base_s: live.base_s * self.st.transfer_corr,
            per_token_s: live.per_token_s * self.st.transfer_corr,
        }
    }

    fn transfer_scale(&self) -> f64 {
        self.st.transfer_corr
    }

    fn parallel_hint(&self) -> f64 {
        self.st.parallelism
    }

    fn learning(&self) -> bool {
        true
    }

    fn observe_parallelism(&mut self, mean_lanes: f64) {
        self.st.parallelism = (1.0 - self.cfg.parallel_alpha) * self.st.parallelism
            + self.cfg.parallel_alpha * mean_lanes;
    }

    fn observe_cloud(&mut self, sim_tokens: usize, observed_s: SimTime) {
        if !observed_s.is_finite() || observed_s < 0.0 {
            return;
        }
        if self.warm_loaded {
            // Drift age-out (ROADMAP item-2 follow-up): grade the
            // warm-started line against the live world. A sustained
            // mismatch means the persisted state describes a world that no
            // longer exists — discard it and re-learn cold rather than
            // slow-walking the decayed accumulators back over hundreds of
            // samples. The triggering sample is absorbed below, as the
            // first observation of the cold restart.
            let pred = self.fit.eval(sim_tokens);
            let off = pred > 1e-9
                && observed_s > 1e-9
                && (pred / observed_s).max(observed_s / pred) > self.cfg.drift_ratio;
            self.drift_streak = if off { self.drift_streak + 1 } else { 0 };
            if self.drift_streak >= self.cfg.drift_samples {
                self.reset_cold();
            }
        }
        let x = sim_tokens as f64;
        // residual against the *current* line, before this sample updates it
        let pred = self.fit.eval(sim_tokens);
        self.st.resid_s = (1.0 - self.cfg.rate_alpha) * self.st.resid_s
            + self.cfg.rate_alpha * (observed_s - pred).abs();
        let d = self.cfg.decay;
        self.st.n = self.st.n * d + 1.0;
        self.st.sx = self.st.sx * d + x;
        self.st.sy = self.st.sy * d + observed_s;
        self.st.sxx = self.st.sxx * d + x * x;
        self.st.sxy = self.st.sxy * d + x * observed_s;
        self.st.cloud_samples += 1;
        self.refit();
    }

    fn observe_edge(&mut self, predicted_s: SimTime, observed_s: SimTime) {
        if let Some(next) = self.ewma_ratio(self.st.edge_corr, observed_s, predicted_s) {
            self.st.edge_corr = next;
            self.st.edge_samples += 1;
        }
    }

    fn observe_transfer(&mut self, predicted_s: SimTime, observed_s: SimTime) {
        if let Some(next) = self.ewma_ratio(self.st.transfer_corr, observed_s, predicted_s) {
            self.st.transfer_corr = next;
            self.st.transfer_samples += 1;
        }
    }

    fn summary(&self) -> CalibSummary {
        CalibSummary {
            learning: true,
            base_f_cloud: self.base,
            f_cloud: self.fit,
            edge_corr: self.st.edge_corr,
            transfer_corr: self.st.transfer_corr,
            parallelism: self.st.parallelism,
            resid_s: self.st.resid_s,
            cloud_samples: self.st.cloud_samples,
            edge_samples: self.st.edge_samples,
            transfer_samples: self.st.transfer_samples,
        }
    }

    fn state(&self) -> Option<CalibState> {
        Some(self.st.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> LatencyFit {
        LatencyFit { a: 0.2, b: 0.055 }
    }

    fn on_cfg() -> CalibCfg {
        CalibCfg { mode: CalibMode::On, ..Default::default() }
    }

    #[test]
    fn default_cfg_validates_and_matches_historical_constants() {
        let c = CalibCfg::default();
        c.validate().unwrap();
        assert_eq!(c.mode, CalibMode::Off);
        // the pre-refactor hardcoded constants, exactly
        assert_eq!(c.parallel_alpha, 0.2);
        assert_eq!(c.rate_alpha, 0.2);
        assert_eq!((c.clamp_lo, c.clamp_hi), (0.25, 4.0));
        // the EWMA complement is bit-exact: 0.8·p + 0.2·x reproduced
        assert_eq!(1.0 - c.parallel_alpha, 0.8);
    }

    #[test]
    fn cfg_validation_rejects_bad_knobs() {
        for bad in [
            CalibCfg { parallel_alpha: -0.1, ..Default::default() },
            CalibCfg { parallel_alpha: 1.5, ..Default::default() },
            CalibCfg { rate_alpha: f64::NAN, ..Default::default() },
            CalibCfg { clamp_lo: 0.0, ..Default::default() },
            CalibCfg { clamp_lo: 2.0, clamp_hi: 1.0, ..Default::default() },
            CalibCfg { decay: 0.0, ..Default::default() },
            CalibCfg { decay: 1.1, ..Default::default() },
            CalibCfg { min_samples: 1, ..Default::default() },
            CalibCfg { drift_ratio: 1.0, ..Default::default() },
            CalibCfg { drift_ratio: f64::INFINITY, ..Default::default() },
            CalibCfg { drift_samples: 0, ..Default::default() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should not validate");
        }
    }

    #[test]
    fn static_fit_is_the_identity_model() {
        let m = StaticFit::new(base(), 0.35, 0.2);
        let live = TransferModel { base_s: 0.02, per_token_s: 5e-7 };
        let t = m.transfer(live);
        assert_eq!((t.base_s, t.per_token_s), (live.base_s, live.per_token_s));
        assert_eq!(m.transfer_scale(), 1.0);
        assert_eq!(m.cost_coeff(), 0.35);
        assert_eq!(m.parallel_hint(), 1.0);
        assert!(!m.learning());
        assert!(m.state().is_none());
    }

    #[test]
    fn static_parallelism_ewma_matches_hardcoded_update() {
        // the exact pre-refactor expression, sample by sample
        let mut m = StaticFit::new(base(), 0.35, 0.2);
        let mut reference = 1.0f64;
        for lanes in [3.0, 1.0, 4.0, 2.5, 2.5, 8.0] {
            m.observe_parallelism(lanes);
            reference = 0.8 * reference + 0.2 * lanes;
            assert_eq!(m.parallel_hint().to_bits(), reference.to_bits());
        }
    }

    #[test]
    fn rate_correction_ewma_clamps_like_the_old_monitor() {
        // RuntimeMonitor::observe_edge_rate's contract, absorbed here: 100
        // wild samples stay inside the clamp
        let mut m = Calibrated::new(base(), 0.35, on_cfg());
        for _ in 0..100 {
            m.observe_edge(1.0, 100.0);
        }
        assert!(m.st.edge_corr <= 4.0, "edge_corr {} escaped clamp", m.st.edge_corr);
        for _ in 0..100 {
            m.observe_transfer(1.0, 1e-9);
        }
        assert!(m.st.transfer_corr >= 0.25 * 0.2, "floor breached");
        assert!(m.st.transfer_corr < 1.0);
    }

    #[test]
    fn calibrated_refit_activates_after_min_samples_and_tracks_truth() {
        let mut m = Calibrated::new(base(), 0.35, on_cfg());
        // the world is actually twice as slow per token as the offline fit
        let real = LatencyFit { a: 0.4, b: 0.11 };
        for i in 0..200usize {
            let l = 32 + (i % 6) * 128;
            m.observe_cloud(l, real.eval(l));
        }
        let f = m.f_cloud();
        assert!((f.b - real.b).abs() / real.b < 0.05, "slope {} vs {}", f.b, real.b);
        assert!((f.a - real.a).abs() < 0.1, "intercept {} vs {}", f.a, real.a);
        // and the slope clamp holds against absurd observations
        let mut wild = Calibrated::new(base(), 0.35, on_cfg());
        for i in 0..50usize {
            let l = 32 + (i % 6) * 128;
            wild.observe_cloud(l, 1e6);
        }
        assert!(wild.f_cloud().b <= base().b * 4.0 + 1e-12);
    }

    #[test]
    fn calibrated_below_min_samples_is_the_offline_line() {
        let mut m = Calibrated::new(base(), 0.35, on_cfg());
        for _ in 0..(m.cfg.min_samples - 1) {
            m.observe_cloud(100, 9.0);
        }
        let f = m.f_cloud();
        assert_eq!((f.a.to_bits(), f.b.to_bits()), (base().a.to_bits(), base().b.to_bits()));
    }

    #[test]
    fn null_calibration_decides_like_static() {
        // rate_alpha 0 + unreachable min_samples: every correction frozen
        // at identity, estimates bit-identical to StaticFit
        let cfg = CalibCfg {
            mode: CalibMode::On,
            rate_alpha: 0.0,
            min_samples: usize::MAX,
            ..Default::default()
        };
        let mut c = Calibrated::new(base(), 0.35, cfg);
        let mut s = StaticFit::new(base(), 0.35, 0.2);
        let live = TransferModel { base_s: 0.025, per_token_s: 6e-7 };
        for (lanes, obs) in [(3.0, 1.7), (2.0, 0.4), (4.0, 9.0)] {
            c.observe_parallelism(lanes);
            c.observe_cloud(200, obs);
            c.observe_edge(1.0, obs);
            c.observe_transfer(0.5, obs);
            s.observe_parallelism(lanes);
        }
        assert_eq!(c.cost_coeff().to_bits(), s.cost_coeff().to_bits());
        assert_eq!(c.f_cloud().b.to_bits(), s.f_cloud().b.to_bits());
        assert_eq!(c.transfer(live).base_s.to_bits(), live.base_s.to_bits());
        assert_eq!(c.transfer_scale().to_bits(), 1.0f64.to_bits());
        assert_eq!(c.parallel_hint().to_bits(), s.parallel_hint().to_bits());
    }

    #[test]
    fn state_round_trip_resumes_exactly() {
        let mut donor = Calibrated::new(base(), 0.35, on_cfg());
        for i in 0..40usize {
            let l = 32 + (i % 6) * 128;
            donor.observe_cloud(l, 0.3 + 0.08 * l as f64);
            donor.observe_edge(1.0, 1.3);
            donor.observe_transfer(0.5, 0.8);
            donor.observe_parallelism(3.0);
        }
        let st = donor.state().unwrap();
        assert!(st.is_finite());
        let mut heir = Calibrated::new(base(), 0.35, on_cfg());
        heir.load_state(&st);
        assert_eq!(heir.f_cloud().a.to_bits(), donor.f_cloud().a.to_bits());
        assert_eq!(heir.f_cloud().b.to_bits(), donor.f_cloud().b.to_bits());
        assert_eq!(heir.cost_coeff().to_bits(), donor.cost_coeff().to_bits());
        assert_eq!(heir.state().unwrap(), st);
        // and both continue identically on the same next observation
        heir.observe_cloud(300, 2.0);
        donor.observe_cloud(300, 2.0);
        assert_eq!(heir.state().unwrap(), donor.state().unwrap());
    }

    #[test]
    fn warm_state_ages_out_under_sustained_drift() {
        // donor learns a much slower world than the offline base; its
        // persisted state warm-starts an heir that actually lives in the
        // base world — sustained off-world residuals must discard the
        // stale state and re-learn cold
        let mut donor = Calibrated::new(base(), 0.35, on_cfg());
        let slow = LatencyFit { a: 2.0, b: 0.5 };
        for i in 0..60usize {
            let l = 32 + (i % 6) * 128;
            donor.observe_cloud(l, slow.eval(l));
        }
        let st = donor.state().unwrap();
        let mut heir = Calibrated::new(base(), 0.35, on_cfg());
        heir.load_state(&st);
        assert!(heir.f_cloud().b > base().b * 1.5, "warm line should be the slow world");
        let n_drift = heir.cfg.drift_samples;
        for _ in 0..(n_drift + 4) {
            heir.observe_cloud(256, base().eval(256));
        }
        let after = heir.state().unwrap();
        assert!(
            after.cloud_samples < st.cloud_samples,
            "stale accumulators survived: {} samples",
            after.cloud_samples
        );
        assert!(!heir.warm_loaded, "age-out must disarm the warm flag");
        // below min_samples again -> effective line is the offline fit
        let f = heir.f_cloud();
        assert_eq!((f.a.to_bits(), f.b.to_bits()), (base().a.to_bits(), base().b.to_bits()));

        // control: an heir whose live world MATCHES the warm state keeps it
        let mut keeper = Calibrated::new(base(), 0.35, on_cfg());
        keeper.load_state(&st);
        let warm_fit = keeper.f_cloud();
        for _ in 0..20 {
            keeper.observe_cloud(256, warm_fit.eval(256));
        }
        assert!(keeper.state().unwrap().cloud_samples >= st.cloud_samples);
        assert!(keeper.warm_loaded, "matching world must not age out");

        // a cold-learning model is never aged out, however wild the world
        let mut cold = Calibrated::new(base(), 0.35, on_cfg());
        for _ in 0..40 {
            cold.observe_cloud(256, 500.0);
        }
        assert!(cold.state().unwrap().cloud_samples == 40);
    }

    #[test]
    fn env_overlay_rejects_garbage() {
        // strict parse: a set-but-bad knob is an error (run single-threaded
        // risk: use a key nothing else reads, then clean up)
        std::env::set_var("PICE_CALIB_DECAY", "fast");
        let r = CalibCfg::default().overlay_env();
        std::env::remove_var("PICE_CALIB_DECAY");
        assert!(r.is_err());
    }
}
