//! Sketch handling: sentence segmentation, sketch-level compression, and
//! the progressive-inference prompt formats.
//!
//! A *sketch* is the LLM's semantically-complete, grammatically-minimal
//! answer outline (paper §II-B): per sentence, the content words survive and
//! the filler words are dropped. The scheduler picks a *sketch level*
//! trading brevity (throughput) against completeness (quality) — paper
//! Challenge 2 — and the edge SLMs expand each sketch sentence back into a
//! full sentence (independently, hence in parallel).

use crate::tokenizer::Tokenizer;

/// How aggressively the sketch compresses the answer. Level 0 = no sketch
/// (full answer from the LLM); higher levels keep fewer content words.
/// `keep_frac` is the fraction of each sentence-sketch retained.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SketchLevel {
    pub level: usize,
    pub keep_frac: f64,
}

/// The scheduler's menu, from "no progressive inference" to "maximal
/// compression" (paper §IV-A2: "multiple sketch length levels, from 0 to l_i").
pub fn levels() -> Vec<SketchLevel> {
    vec![
        SketchLevel { level: 0, keep_frac: 0.0 }, // disabled: full LLM answer
        SketchLevel { level: 1, keep_frac: 1.0 }, // full sketch (all content words)
        SketchLevel { level: 2, keep_frac: 0.8 },
        SketchLevel { level: 3, keep_frac: 0.6 },
    ]
}

/// Split a generated token stream into sentences at "." boundaries.
pub fn split_sentences(tokens: &[u32], period: u32) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    let mut cur = Vec::new();
    for &t in tokens {
        cur.push(t);
        if t == period {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Split a sketch token stream into per-sentence sketches at ";" boundaries.
pub fn split_sketch(tokens: &[u32], semicolon: u32) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    let mut cur = Vec::new();
    for &t in tokens {
        if t == semicolon {
            if !cur.is_empty() {
                out.push(std::mem::take(&mut cur));
            }
        } else {
            cur.push(t);
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Apply a sketch level to a full per-sentence sketch: keep the first
/// ceil(keep_frac * n) content words (leading words carry the head of the
/// semantic dependency in our templates, mirroring how the paper's
/// fine-tuned LLM drops trailing qualifiers first).
pub fn compress(sentence_sketch: &[u32], level: SketchLevel) -> Vec<u32> {
    if level.level == 0 {
        return sentence_sketch.to_vec();
    }
    let n = sentence_sketch.len();
    let keep = ((n as f64) * level.keep_frac).ceil().max(1.0) as usize;
    sentence_sketch[..keep.min(n)].to_vec()
}

/// Expected sketch length in tokens for a predicted answer length, given a
/// level (used by the scheduler's Eq. 2 feasibility test before the sketch
/// exists). Calibrated on the corpus: sketches are ~55% of full length, and
/// levels shave that down by keep_frac.
pub fn expected_sketch_len(predicted_answer_len: usize, level: SketchLevel) -> usize {
    if level.level == 0 {
        return predicted_answer_len;
    }
    ((predicted_answer_len as f64) * 0.55 * level.keep_frac).ceil() as usize
}

/// Prompt assembly for the three progressive-inference stages. All prompts
/// are pure token sequences in the picoLM training formats.
pub struct Prompts;

impl Prompts {
    /// Cloud LLM, full answer: `<q> q <a>` — generate until <eos>.
    pub fn full_answer(tok: &Tokenizer, question: &[u32]) -> Vec<u32> {
        let sp = &tok.specials;
        let mut p = vec![sp.q];
        p.extend_from_slice(question);
        p.push(sp.a);
        p
    }

    /// Cloud LLM, sketch: `<q> q <sk>` — generate until <eos>.
    pub fn sketch(tok: &Tokenizer, question: &[u32]) -> Vec<u32> {
        let sp = &tok.specials;
        let mut p = vec![sp.q];
        p.extend_from_slice(question);
        p.push(sp.sk);
        p
    }

    /// Edge SLM expansion of one sketch sentence — the paper's template
    /// ("I have a question about {query}. The simplification answer is as
    /// follows: {sketch}. Now, please help me complete ... {sentence}"):
    /// `<q> q <sk> full-sketch <ex> sentence-sketch <a>` — generate one
    /// sentence (until "." or <eos>).
    pub fn expand(
        tok: &Tokenizer,
        question: &[u32],
        full_sketch: &[u32],
        sentence_sketch: &[u32],
    ) -> Vec<u32> {
        let sp = &tok.specials;
        let mut p = vec![sp.q];
        p.extend_from_slice(question);
        p.push(sp.sk);
        p.extend_from_slice(full_sketch);
        p.push(sp.ex);
        p.extend_from_slice(sentence_sketch);
        p.push(sp.a);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests_support::toy_corpus;

    #[test]
    fn split_sentences_at_periods() {
        let period = 7;
        let toks = [1, 2, period, 3, 4, period, 5];
        let s = split_sentences(&toks, period);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], vec![1, 2, period]);
        assert_eq!(s[2], vec![5]);
    }

    #[test]
    fn split_sketch_at_semicolons() {
        let semi = 8;
        let toks = [1, 2, semi, 3, semi, semi, 4];
        let s = split_sketch(&toks, semi);
        assert_eq!(s, vec![vec![1, 2], vec![3], vec![4]]);
    }

    #[test]
    fn compress_levels() {
        let sk = [10, 11, 12, 13, 14];
        let lv = levels();
        assert_eq!(compress(&sk, lv[1]), sk.to_vec());
        assert_eq!(compress(&sk, lv[2]).len(), 4); // ceil(5*0.8)
        assert_eq!(compress(&sk, lv[3]).len(), 3); // ceil(5*0.6)
    }

    #[test]
    fn compress_never_empty() {
        let sk = [10];
        for lv in levels().into_iter().skip(1) {
            assert_eq!(compress(&sk, lv).len(), 1);
        }
    }

    #[test]
    fn expected_len_monotone_in_level() {
        let lv = levels();
        let l1 = expected_sketch_len(100, lv[1]);
        let l2 = expected_sketch_len(100, lv[2]);
        let l3 = expected_sketch_len(100, lv[3]);
        assert!(l1 > l2 && l2 > l3 && l3 > 0);
        assert_eq!(expected_sketch_len(100, lv[0]), 100);
    }

    #[test]
    fn prompts_well_formed() {
        let (c, tok) = toy_corpus();
        let q = &c.questions[0];
        let sp = &tok.specials;
        let full_sketch = q.sketch_tokens(sp.semicolon);
        let p = Prompts::expand(&tok, &q.question, &full_sketch, &q.sentences[0].sketch);
        assert_eq!(p[0], sp.q);
        assert!(p.contains(&sp.sk));
        assert!(p.contains(&sp.ex));
        assert_eq!(*p.last().unwrap(), sp.a);
    }
}
