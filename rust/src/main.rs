//! `pice` — the leader binary: serve workloads, inspect the model registry,
//! run the offline profiler, and run the RLAIF sketch fine-tuning.
//!
//! ```text
//! pice serve   [--model llama70b-sim] [--rpm 30] [--n 60] [--policy pice|cloud|edge|routing]
//! pice models
//! pice profile [--edges 4]
//! pice finetune [--pairs 8] [--steps 30]
//! pice eval    [--model llama70b-sim] [--n 40]
//! ```

use pice::cli::Args;
use pice::cluster::{Cluster, DeviceSpec};
use pice::finetune::{Trainer, TrainerCfg};
use pice::metrics::Mode;
use pice::models::ModelInfo;
use pice::profiler::OfflineProfile;
use pice::quality::judge::Judge;
use pice::scenario::Env;
use pice::util::stats;
use pice::{baselines, info};

fn main() {
    let args = Args::from_env();
    if args.has_flag("quiet") {
        pice::util::set_log_level(0);
    }
    let result = match args.subcommand.as_deref() {
        Some("serve") => serve(&args),
        Some("models") => models(),
        Some("profile") => profile(&args),
        Some("finetune") => finetune(&args),
        Some("eval") => eval(&args),
        _ => {
            eprintln!(
                "usage: pice <serve|models|profile|finetune|eval> [options]\n\
                 see `cargo run --example quickstart` for the runtime path"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn serve(args: &Args) -> Result<(), String> {
    let model = args.opt_str("model", "llama70b-sim").to_string();
    let n = args.opt_usize("n", 60);
    let mut env = Env::load()?;
    let rpm = args.opt_f64("rpm", env.paper_rpm(&model));
    let cfg = match args.opt_str("policy", "pice") {
        "cloud" => baselines::cloud_only(&model),
        "edge" => baselines::edge_only(&model),
        "routing" => baselines::routing(&model),
        _ => baselines::pice(&model),
    };
    info!("serving {n} requests at {rpm:.0} rpm on {model} ({:?})", cfg.policy);
    let wl = env.workload(rpm, n, args.opt_usize("seed", 11) as u64);
    let judge = Judge::fit(&env.corpus);
    let (m, traces) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
    let scores: Vec<f64> = traces
        .iter()
        .filter_map(|t| env.corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall))
        .collect();
    println!("throughput      {:.2} queries/min", m.throughput_qpm);
    println!("avg latency     {:.2} s (p50 {:.2}, p95 {:.2})", m.avg_latency_s, m.p50_latency_s, m.p95_latency_s);
    println!("judge quality   {:.2} / 10", stats::mean(&scores));
    println!("server tokens   {}", m.server_tokens);
    println!("edge tokens     {}", m.edge_tokens);
    println!(
        "progressive     {} / {} requests",
        traces.iter().filter(|t| t.mode == Mode::Progressive).count(),
        m.n_requests
    );
    Ok(())
}

fn models() -> Result<(), String> {
    let env = Env::load()?;
    println!(
        "{:<14} {:>9} {:>10} {:>6} | {:>8} {:>7} {:>8} {:>9}",
        "model", "speed t/s", "memory GB", "MMLU", "d_model", "layers", "params", "eval acc"
    );
    for m in &env.registry.models {
        println!(
            "{:<14} {:>9.2} {:>10.2} {:>6.1} | {:>8} {:>7} {:>8} {:>9.3}",
            m.name, m.speed_tps, m.memory_gb, m.mmlu, m.d_model, m.n_layers, m.n_params, m.eval_accuracy
        );
    }
    Ok(())
}

fn profile(args: &Args) -> Result<(), String> {
    let env = Env::load()?;
    let cluster = Cluster::testbed(args.opt_usize("edges", 4));
    let devices: Vec<&DeviceSpec> =
        std::iter::once(&cluster.cloud).chain(cluster.edges.iter().take(1)).collect();
    let models: Vec<&ModelInfo> = env.registry.models.iter().collect();
    let prof = OfflineProfile::profile_batched(&devices, &models, 16);
    println!("offline latency fits f(l) = a + b*l  [seconds; cloud at batch 16]");
    for d in &devices {
        for m in &models {
            if let Some(fit) = prof.f(&d.name, &m.name) {
                println!("  {:<8} {:<14} a={:>7.3}  b={:>8.5}  f(500)={:>7.1}s", d.name, m.name, fit.a, fit.b, fit.eval(500));
            } else {
                println!("  {:<8} {:<14} OOM", d.name, m.name);
            }
        }
    }
    for slm in env.registry.slms_for("qwen72b-sim") {
        if let Some(c) = prof.cost_coefficient("cloud-0", "qwen72b-sim", "edge-0", &slm.name) {
            println!("cost coefficient c (72B cloud vs {} edge) = {c:.2}", slm.name);
        }
    }
    Ok(())
}

fn finetune(args: &Args) -> Result<(), String> {
    let mut env = Env::load()?;
    let trainer = Trainer {
        cfg: TrainerCfg {
            pairs_per_category: args.opt_usize("pairs", 8),
            rl_steps: args.opt_usize("steps", 30),
            ..Default::default()
        },
        corpus: env.corpus.clone(),
        tok: &env.tok,
    };
    let out = trainer.run(env.backend.as_mut())?;
    println!(
        "reward model: {} pairs, train loss {:.3}, holdout accuracy {:.2}",
        out.n_pairs, out.rm_train_loss, out.rm_holdout_acc
    );
    println!("fine-tuned keep-fractions per category:");
    for (cat, frac) in &out.policy.keep_frac {
        println!("  {cat:<16} {frac:.2}");
    }
    Ok(())
}

fn eval(args: &Args) -> Result<(), String> {
    let model = args.opt_str("model", "llama70b-sim").to_string();
    let n = args.opt_usize("n", 40);
    let mut env = Env::load()?;
    let rpm = env.paper_rpm(&model);
    let judge = Judge::fit(&env.corpus);
    println!("{:<11} {:>10} {:>9} {:>8}", "system", "thpt(q/m)", "lat(s)", "quality");
    for (name, result) in env.run_all_systems(&model, rpm, n, 11) {
        match result {
            Err(e) => println!("{name:<11} {e}"),
            Ok((m, traces)) => {
                let scores: Vec<f64> = traces
                    .iter()
                    .filter_map(|t| {
                        env.corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall)
                    })
                    .collect();
                println!(
                    "{name:<11} {:>10.2} {:>9.2} {:>8.2}",
                    m.throughput_qpm, m.avg_latency_s, stats::mean(&scores)
                );
            }
        }
    }
    Ok(())
}
