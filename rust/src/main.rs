//! `pice` — the leader binary: serve workloads, inspect the model registry,
//! run the offline profiler, and run the RLAIF sketch fine-tuning.
//!
//! ```text
//! pice serve   [--model llama70b-sim] [--rpm 30] [--n 60] [--policy pice|cloud|edge|routing]
//!              [--seed 11] [--max-inflight 256] [--stream]
//!              [--dynamics stable|flaky-wan|edge-churn|shard-blackout] [--deadline <s>]
//!              [--shards 4] [--placement hash|least-loaded]
//!              [--calibrate on|off|warm]
//!              [--hedge <quantile|off>] [--slot-timeout-mult <x>]
//!              [--trace-out <path>] [--metrics-out <path>] [--watch]
//! pice models
//! pice profile [--edges 4]
//! pice finetune [--pairs 8] [--steps 30]
//! pice eval    [--model llama70b-sim] [--n 40]
//! pice help | pice <subcommand> --help
//! ```

use pice::cli::Args;
use pice::cluster::{Cluster, DeviceSpec};
use pice::costmodel::CalibMode;
use pice::dynamics::DynamicsSpec;
use pice::finetune::{Trainer, TrainerCfg};
use pice::fleet::{FleetCfg, Placement};
use pice::metrics::Mode;
use pice::models::ModelInfo;
use pice::profiler::OfflineProfile;
use pice::quality::judge::Judge;
use pice::scenario::Env;
use pice::serve::{PiceService, ResponseEventKind, ServeCfg};
use pice::sweep::cache::CacheStats;
use pice::telemetry::{self, MetricsRegistry, SnapshotWriter};
use pice::util::json::{num, obj, Json};
use pice::util::stats;
use pice::{baselines, info};

const USAGE: &str = "usage: pice <serve|models|profile|finetune|eval|help> [options]\n\
                     run `pice help` for the full option and knob reference";

const HELP: &str = "\
pice — semantic-driven progressive inference for LLM serving (PICE reproduction)

SUBCOMMANDS
  serve     serve a generated workload through one policy
              --model <name>        cloud LLM (default llama70b-sim)
              --rpm <f>             request rate (default: 1.5x cloud max batch)
              --n <int>             number of requests (default 60)
              --policy <p>          pice | cloud | edge | routing (default pice)
              --seed <int>          workload seed (default 11)
              --max-inflight <int>  admission bound; excess submissions are
                                    rejected with a terminal event (default 256)
              --deadline <s>        per-request SLO deadline: submissions whose
                                    backlog estimate already exceeds it are
                                    rejected up-front as infeasible
              --dynamics <preset>   environment dynamics (PERF.md §Dynamics):
                                      stable     static world (the default)
                                      flaky-wan  bandwidth walk + congestion spikes
                                      edge-churn edge crash/recover + stragglers,
                                                 with failover re-dispatch
                                      shard-blackout  whole-shard blackout windows
                                                 (fleet failover / backoff drill)
              --stream              print the live per-request response-event log
                                    (Admitted / SketchReady / ExpansionChunk / Final)
              --shards <int>        serve through a fleet of N engine shards,
                                    each with its own cluster replica and fault
                                    timeline (default 1: the single engine)
              --placement <p>       fleet session placement (PERF.md §Fleet):
                                      hash          deterministic session-hash
                                                    (default; bit-stable traces)
                                      least-loaded  route to the shard with the
                                                    smallest backlog estimate
              --calibrate <m>       cost-model calibration (PERF.md §Calibrated
                                    cost model):
                                      off   static offline fit (default;
                                            bit-identical legacy behavior)
                                      on    re-fit Eq. 2's estimates online from
                                            this run's observed latencies
                                      warm  on + seed from the PICE_CALIB_PATH
                                            store (cold start when absent);
                                            learned state is deposited back
                                    prints a calibration summary with the metrics
              --hedge <q|off>       tail tolerance (PERF.md §Tail tolerance):
                                    arm a watchdog at the q-th quantile (q in
                                    (0,1), e.g. 0.95) of each expansion pull's
                                    Eq. 2 estimate; on expiry the straggling
                                    pull is hedged — still-pending slots are
                                    speculatively re-dispatched to another up
                                    edge or the cloud, first completion wins.
                                    Also turns on blackout backoff retries and
                                    (with --shards) cross-shard re-dispatch of
                                    a dead shard's queued sessions.
                                    off = default: bit-identical legacy traces
              --slot-timeout-mult <x>  multiplier on the hedge timeout
                                    (default 1.0; requires --hedge <q>)
              --trace-out <path>    telemetry (PERF.md §Telemetry): write the
                                    request-span log as Chrome-trace JSONL
                                    (Perfetto ingests it directly; pid = shard,
                                    tid = request id) and print the per-phase
                                    latency breakdown with the metrics
              --metrics-out <path>  telemetry: write sim-time-paced metrics
                                    snapshots as JSONL, one line every 5
                                    sim-seconds plus a final end-of-run line
                                    folding in cache / calibration / run stats;
                                    each push atomically rewrites the file, so
                                    an interrupted run keeps its last snapshot
              --watch               telemetry: print a one-line human summary
                                    at every snapshot instant (no file needed)
  models    print the model registry (speed, memory, MMLU, eval accuracy)
  profile   offline latency fits f(l) per (device, model)
              --edges <int>         edge count of the profiled testbed (default 4)
  finetune  RLAIF sketch-policy fine-tuning
              --pairs <int>         preference pairs per category (default 8)
              --steps <int>         RL steps (default 30)
  eval      run all four systems (PICE + baselines) on one workload
              --model <name>        cloud LLM (default llama70b-sim)
              --n <int>             number of requests (default 40)

GLOBAL FLAGS
  --quiet   suppress info logging
  --help    this text (also `pice help`)

ENVIRONMENT KNOBS (serve/bench execution layer — see PERF.md)
  PICE_BACKEND=surrogate   force the deterministic surrogate backend
  PICE_ARTIFACTS=<dir>     artifacts directory (default ./artifacts)
  PICE_WORKERS=<n>         backend worker pool (unset: auto-size, cap 8)
  PICE_SWEEP_THREADS=<n>   scenario-sweep pool for grid benches (unset: auto)
  PICE_MEMO_CAP=<n>        generation memo-cache entry cap (default 4096, 0 = off)
  PICE_CACHE_BUDGET=<b>    resident-byte budget for the cache's buffer pool
                           (k/m/g suffixes; 0 = off; overrides PICE_MEMO_CAP;
                           cold pages spill to PICE_MEMO_PATH when set)
  PICE_MEMO_PATH=<path>    persist the memo cache across processes (paged
                           store directory; v1 snapshot files auto-migrate)
  PICE_BENCH_N=<n>         requests per bench scenario (default 60)
  PICE_BENCH_SMOKE=1       tiny CI sizing for benches
  PICE_SINGLE_FIFO=1       ablate Algorithm 1 into one FIFO list
  PICE_CALIB_PATH=<path>   persist learned calibration (--calibrate warm)
  PICE_CALIB_PARALLEL_ALPHA / PICE_CALIB_RATE_ALPHA    EWMA gains in [0,1]
  PICE_CALIB_CLAMP=<lo,hi> correction-ratio clamp (default 0.25,4)
  PICE_CALIB_DECAY=<f>     regression sample decay in (0,1] (default 0.995)
  PICE_CALIB_MIN_SAMPLES=<n>  cloud samples before the re-fit engages";

/// Flags accepted by every subcommand.
const GLOBAL_FLAGS: &[&str] = &["quiet", "help"];

/// The global flags plus a subcommand's own.
fn with_global_flags(extra: &[&'static str]) -> Vec<&'static str> {
    GLOBAL_FLAGS.iter().chain(extra).copied().collect()
}

fn main() {
    let args = Args::from_env();
    if args.has_flag("quiet") {
        pice::util::set_log_level(0);
    }
    if args.has_flag("help") || args.subcommand.as_deref() == Some("help") {
        println!("{HELP}");
        return;
    }
    let result = match args.subcommand.as_deref() {
        Some("serve") => args
            .validate(
                &[
                    "model",
                    "rpm",
                    "n",
                    "policy",
                    "seed",
                    "max-inflight",
                    "dynamics",
                    "deadline",
                    "shards",
                    "placement",
                    "calibrate",
                    "hedge",
                    "slot-timeout-mult",
                    "trace-out",
                    "metrics-out",
                ],
                &with_global_flags(&["stream", "watch"]),
            )
            .and_then(|()| serve(&args)),
        Some("models") => args.validate(&[], GLOBAL_FLAGS).and_then(|()| models()),
        Some("profile") => args.validate(&["edges"], GLOBAL_FLAGS).and_then(|()| profile(&args)),
        Some("finetune") => {
            args.validate(&["pairs", "steps"], GLOBAL_FLAGS).and_then(|()| finetune(&args))
        }
        Some("eval") => args.validate(&["model", "n"], GLOBAL_FLAGS).and_then(|()| eval(&args)),
        Some(other) => Err(format!("unknown subcommand `{other}`\n{USAGE}")),
        None => {
            eprintln!("{USAGE}");
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn serve(args: &Args) -> Result<(), String> {
    let model = args.opt_str("model", "llama70b-sim").to_string();
    let n = args.opt_usize("n", 60);
    let stream = args.has_flag("stream");
    // Telemetry knobs (PERF.md §Telemetry). Any of them turns the span /
    // registry machinery on; all absent leaves the engines bit-identical
    // to a build without the telemetry module.
    let trace_out = args.opt("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.opt("metrics-out").map(std::path::PathBuf::from);
    let watch = args.has_flag("watch");
    let telemetry_on = trace_out.is_some() || metrics_out.is_some() || watch;
    let mut env = Env::load()?;
    let rpm = args.opt_f64("rpm", env.paper_rpm(&model));
    let mut cfg = match args.opt_str("policy", "pice") {
        "cloud" => baselines::cloud_only(&model),
        "edge" => baselines::edge_only(&model),
        "routing" => baselines::routing(&model),
        _ => baselines::pice(&model),
    };
    if let Some(preset) = args.opt("dynamics") {
        cfg.dynamics = DynamicsSpec::preset(preset).ok_or_else(|| {
            format!(
                "unknown dynamics preset `{preset}`; valid presets: {}",
                DynamicsSpec::preset_names().join(", ")
            )
        })?;
    }
    match args.opt("hedge") {
        None | Some("off") => {}
        Some(v) => {
            let q: f64 = v.parse().map_err(|_| {
                format!("--hedge expects `off` or a quantile in (0, 1), got `{v}` (e.g. --hedge 0.95)")
            })?;
            // q = 0 never fires and q = 1 gives an infinite timeout; both are
            // spelled `off`, and anything outside is a user error
            if !q.is_finite() || q <= 0.0 || q >= 1.0 {
                return Err(format!("--hedge quantile must be strictly inside (0, 1), got `{v}`"));
            }
            cfg.tail.hedge_quantile = Some(q);
        }
    }
    if let Some(v) = args.opt("slot-timeout-mult") {
        if cfg.tail.hedge_quantile.is_none() {
            return Err("--slot-timeout-mult only scales the --hedge watchdog; pass --hedge <quantile> too".to_string());
        }
        let x: f64 = v.parse().map_err(|_| {
            format!("--slot-timeout-mult expects a number, got `{v}` (e.g. --slot-timeout-mult 1.5)")
        })?;
        if !x.is_finite() || x <= 0.0 {
            return Err(format!("--slot-timeout-mult must be a positive finite number, got `{v}`"));
        }
        cfg.tail.slot_timeout_mult = x;
    }
    let calib_mode = match args.opt("calibrate") {
        None | Some("off") => CalibMode::Off,
        Some("on") => CalibMode::On,
        Some("warm") => CalibMode::Warm,
        Some(other) => {
            return Err(format!("--calibrate expects on|off|warm, got `{other}`"));
        }
    };
    env.apply_calib(&mut cfg, calib_mode);
    // PICE_CALIB_* knobs overlay the defaults; garbage is an error, not a
    // silent fallback (a mistyped gain would quietly change the model)
    cfg.calib = cfg.calib.overlay_env()?;
    info!("serving {n} requests at {rpm:.0} rpm on {model} ({:?})", cfg.policy);
    let wl = env.workload(rpm, n, args.opt_usize("seed", 11) as u64);
    let corpus = env.corpus.clone();
    let judge = Judge::fit(&corpus);
    let deadline_s = match args.opt("deadline") {
        Some(v) => {
            let d: f64 = v.parse().map_err(|_| {
                format!("--deadline expects seconds as a number, got `{v}` (e.g. --deadline 12.5)")
            })?;
            // NaN would silently disable the gate (every comparison false)
            // and a non-positive bound rejects everything — both are
            // user errors, not configurations
            if !d.is_finite() || d <= 0.0 {
                return Err(format!("--deadline must be a positive finite number, got `{v}`"));
            }
            Some(d)
        }
        None => None,
    };
    let serve_cfg = ServeCfg { max_inflight: args.opt_usize("max-inflight", 256), deadline_s };
    let shards = args.opt_usize("shards", 1);
    let shards_invalid = match args.opt("shards") {
        Some(v) => v.parse::<usize>().is_err(),
        None => false,
    };
    if shards == 0 || shards_invalid {
        return Err("--shards expects a positive integer (e.g. --shards 4)".to_string());
    }
    let placement = match args.opt("placement") {
        Some(p) => Placement::parse(p).ok_or_else(|| {
            format!("unknown placement `{p}`; valid placements: hash, least-loaded")
        })?,
        None => Placement::Hash,
    };
    // Asking for a fleet knob (even `--shards 1`) routes through the fleet
    // service — a 1-shard hash fleet is bit-identical to the single engine.
    let fleet_mode = args.opt("shards").is_some() || args.opt("placement").is_some();

    // The service (open-loop) path runs when its knobs are engaged: --stream
    // for the live log, an explicit --max-inflight for admission control, an
    // SLO --deadline, a fleet shape, calibration (the summary and the
    // persistable state live on the service's engines), or telemetry (the
    // snapshot exporter paces itself on the service clock). Without any, the
    // closed-loop driver produces bit-identical traces with no event
    // machinery.
    let mut snap = metrics_out.as_ref().map(SnapshotWriter::new);
    let (traces, rejected, shard_routes, calib_out, telem) = if fleet_mode
        || stream
        || telemetry_on
        || args.opt("max-inflight").is_some()
        || deadline_s.is_some()
        || calib_mode != CalibMode::Off
    {
        // Open-loop serving: submit each arrival as simulated time reaches
        // it, pumping the engine(s) between submissions.
        let mut svc = if fleet_mode {
            env.fleet_service(cfg, serve_cfg, FleetCfg { shards, placement })
                .map_err(|e| e.to_string())?
        } else {
            env.service(cfg, serve_cfg).map_err(|e| e.to_string())?
        };
        if telemetry_on {
            svc.enable_telemetry();
        }
        let mut next_snap = SNAPSHOT_EVERY_S;
        for r in &wl.requests {
            // Pace the snapshot exporter on sim time: stop at every
            // 5-sim-second boundary the next arrival would jump over.
            while telemetry_on && next_snap <= r.arrival_s {
                svc.pump_until(next_snap).map_err(|e| e.to_string())?;
                snapshot_tick(&mut svc, next_snap, &mut snap, watch)?;
                next_snap += SNAPSHOT_EVERY_S;
            }
            svc.pump_until(r.arrival_s).map_err(|e| e.to_string())?;
            svc.submit(r.question_id, r.arrival_s).map_err(|e| e.to_string())?;
            if stream {
                while let Some(ev) = svc.poll_any() {
                    print_event(&ev);
                }
            }
        }
        svc.pump_all().map_err(|e| e.to_string())?;
        if stream {
            while let Some(ev) = svc.poll_any() {
                print_event(&ev);
            }
        }
        let rejected = svc.rejected();
        let routes = svc.shard_routes().to_vec();
        let calib_out = (calib_mode != CalibMode::Off)
            .then(|| (svc.calib_summaries(), svc.calib_states()));
        // Drain the telemetry before `finish` consumes the service; the
        // final snapshot is written after the run so it can fold in the
        // cache / calibration / run stats (satellite: an interrupted run
        // still has the last periodic snapshot on disk).
        let telem = telemetry_on
            .then(|| (svc.take_spans(), svc.metrics_registries(), svc.shard_gauges()));
        (svc.finish().map_err(|e| e.to_string())?, rejected, routes, calib_out, telem)
    } else {
        // closed-loop batch driver (same traces, no event machinery)
        let (_, traces) = env.run(cfg, &wl).map_err(|e| e.to_string())?;
        (traces, 0, Vec::new(), None, None)
    };

    let mut m = pice::metrics::aggregate(&traces);
    if let Some((spans, _, _)) = &telem {
        m.phases = telemetry::phase_breakdown(spans);
    }
    let scores: Vec<f64> = traces
        .iter()
        .filter_map(|t| corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall))
        .collect();
    println!("throughput      {:.2} queries/min", m.throughput_qpm);
    println!(
        "avg latency     {:.2} s (p50 {:.2}, p95 {:.2}, p99.9 {:.2})",
        m.avg_latency_s, m.p50_latency_s, m.p95_latency_s, m.p999_latency_s
    );
    if let Some(pb) = &m.phases {
        println!(
            "phase p50/p99   queue {:.2}/{:.2} | cloud {:.2}/{:.2} | transfer {:.2}/{:.2} \
             | edge {:.2}/{:.2} | tail {:.2}/{:.2} s",
            pb.queue.p50_s,
            pb.queue.p99_s,
            pb.cloud.p50_s,
            pb.cloud.p99_s,
            pb.transfer.p50_s,
            pb.transfer.p99_s,
            pb.edge.p50_s,
            pb.edge.p99_s,
            pb.tail.p50_s,
            pb.tail.p99_s
        );
    }
    println!("first sketch    p50 {:.2} s, p99 {:.2} s", m.p50_ttfs_s, m.p99_ttfs_s);
    println!("first expansion p50 {:.2} s, p99 {:.2} s", m.p50_ttfe_s, m.p99_ttfe_s);
    println!("judge quality   {:.2} / 10", stats::mean(&scores));
    println!("server tokens   {}", m.server_tokens);
    println!("edge tokens     {}", m.edge_tokens);
    if m.failovers > 0 {
        println!(
            "failovers       {} ({} slots re-queued; degraded p99 {:.2} s)",
            m.failovers, m.retried_slots, m.p99_degraded_latency_s
        );
    }
    println!(
        "progressive     {} / {} requests ({} rejected by admission)",
        traces.iter().filter(|t| t.mode == Mode::Progressive).count(),
        m.n_requests,
        rejected
    );
    if m.salvaged_slots > 0 {
        println!("salvaged        {} expansion slots kept across edge crashes", m.salvaged_slots);
    }
    if m.hedges > 0 {
        println!("hedges          {} straggling pulls duplicated ({} slots re-dispatched)", m.hedges, m.hedged_slots);
    }
    if m.requeue_retries > 0 {
        println!("requeue retries {} deferred admissions under queue pressure", m.requeue_retries);
    }
    if let Some(cs) = env.cache_stats() {
        if cs.lookups() > 0 {
            let skipped = if cs.skipped_nonfinite > 0 {
                format!(" | {} non-finite skipped", cs.skipped_nonfinite)
            } else {
                String::new()
            };
            println!(
                "memo cache      {:.0}% hit ({:.0}% cross) | {} evictions, {} pages spilled, \
                 {} faulted | {:.1} MiB resident{skipped}",
                cs.hit_rate() * 100.0,
                cs.cross_hit_rate() * 100.0,
                cs.evictions,
                cs.spilled_pages,
                cs.faulted_pages,
                cs.resident_bytes as f64 / (1024.0 * 1024.0),
            );
        }
    }
    // Telemetry exporters: the span log as Chrome-trace JSONL, and one
    // final snapshot line folding in the end-of-run cache / calibration /
    // run stats — so a metrics file always closes with a complete summary.
    if let Some((spans, regs, gauges)) = &telem {
        let t_final = traces.iter().map(|t| t.done).fold(0.0, f64::max);
        if let Some(path) = &trace_out {
            telemetry::write_chrome_trace(path, spans).map_err(|e| e.to_string())?;
            info!("wrote {} trace events to {}", spans.len(), path.display());
        }
        let cache = env.cache_stats();
        let line = snapshot_json(
            t_final,
            true,
            regs.as_ref(),
            gauges,
            0,
            rejected,
            cache.as_ref(),
            calib_out.as_ref().map(|(sm, _)| sm.as_slice()),
            Some(&m),
        );
        if let Some(w) = &mut snap {
            w.push(line).map_err(|e| e.to_string())?;
            if let Some(path) = &metrics_out {
                info!("wrote {} metrics snapshots to {}", w.len(), path.display());
            }
        }
        if watch {
            print_watch(t_final, regs.as_ref(), gauges, 0);
        }
    }
    if let Some((summaries, states)) = calib_out {
        if summaries.len() == 1 {
            println!("calibration     {}", summaries[0]);
        } else {
            for (s, cs) in summaries.iter().enumerate() {
                println!("calibration s{s}  {cs}");
            }
        }
        // deposit learned state into the PICE_CALIB_PATH store (saved when
        // the Env drops). A fleet's shards all map to the same key and put()
        // is last-wins, so record in reverse shard order: shard 0 — the
        // shard bit-identical to the single-engine world — prevails.
        for (key, st) in states.into_iter().rev() {
            env.calib_record(&key, st);
        }
    }
    // Per-shard breakdown: fleet-wide numbers above are computed over the
    // union of traces (never by summing per-shard rates — see
    // metrics::aggregate_shards); here each shard's own slice.
    if shards > 1 {
        let mut by_shard: Vec<Vec<pice::metrics::RequestTrace>> = vec![Vec::new(); shards];
        for t in &traces {
            if let Some(s) = shard_routes.get(t.rid).copied().flatten() {
                by_shard[s].push(t.clone());
            }
        }
        let fm = pice::metrics::aggregate_shards(&by_shard);
        println!("fleet           {shards} shards, {} placement", placement.name());
        for (s, sm) in fm.per_shard.iter().enumerate() {
            println!(
                "  shard {s}       {:>3} reqs | {:.2} q/m | lat p50 {:.2}s p95 {:.2}s \
                 | {} failovers",
                sm.n_requests, sm.throughput_qpm, sm.p50_latency_s, sm.p95_latency_s, sm.failovers
            );
        }
    }
    Ok(())
}

/// Sim-seconds between periodic telemetry snapshots (`--metrics-out`).
const SNAPSHOT_EVERY_S: f64 = 5.0;

/// Emit one snapshot at instant `t` (the service has already been pumped
/// to it): a JSONL line into `snap` and/or the `--watch` summary line.
fn snapshot_tick(
    svc: &mut PiceService<'_>,
    t: f64,
    snap: &mut Option<SnapshotWriter>,
    watch: bool,
) -> Result<(), String> {
    let regs = svc.metrics_registries();
    let gauges = svc.shard_gauges();
    let inflight = svc.inflight();
    let line =
        snapshot_json(t, false, regs.as_ref(), &gauges, inflight, svc.rejected(), None, None, None);
    if let Some(w) = snap {
        w.push(line).map_err(|e| e.to_string())?;
    }
    if watch {
        print_watch(t, regs.as_ref(), &gauges, inflight);
    }
    Ok(())
}

/// One snapshot object (the `--metrics-out` JSONL schema — PERF.md
/// §Telemetry). `regs` is the `(fleet-merged, per-shard)` registry pair;
/// `cache` / `calib` / `run` are folded into the final line only.
#[allow(clippy::too_many_arguments)]
fn snapshot_json(
    t: f64,
    is_final: bool,
    regs: Option<&(MetricsRegistry, Vec<MetricsRegistry>)>,
    gauges: &[(f64, usize)],
    inflight: usize,
    rejected: usize,
    cache: Option<&CacheStats>,
    calib: Option<&[pice::costmodel::CalibSummary]>,
    run: Option<&pice::metrics::RunMetrics>,
) -> Json {
    let mut fields = vec![
        ("t", num(t)),
        ("final", Json::Bool(is_final)),
        ("inflight", num(inflight as f64)),
        ("rejected", num(rejected as f64)),
        (
            "shards",
            Json::Arr(
                gauges
                    .iter()
                    .enumerate()
                    .map(|(shard, (backlog_s, up_edges))| {
                        obj(vec![
                            ("shard", num(shard as f64)),
                            ("backlog_s", num(*backlog_s)),
                            ("up_edges", num(*up_edges as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some((fleet, per_shard)) = regs {
        fields.push(("metrics", fleet.to_json()));
        if per_shard.len() > 1 {
            fields
                .push(("per_shard", Json::Arr(per_shard.iter().map(|r| r.to_json()).collect())));
        }
    }
    if let Some(cs) = cache {
        fields.push((
            "cache",
            obj(vec![
                ("lookups", num(cs.lookups() as f64)),
                ("hit_rate", num(cs.hit_rate())),
                ("cross_hit_rate", num(cs.cross_hit_rate())),
                ("evictions", num(cs.evictions as f64)),
                ("spilled_pages", num(cs.spilled_pages as f64)),
                ("faulted_pages", num(cs.faulted_pages as f64)),
                ("resident_bytes", num(cs.resident_bytes as f64)),
            ]),
        ));
    }
    if let Some(summaries) = calib {
        fields.push((
            "calib",
            Json::Arr(summaries.iter().map(|c| pice::util::json::s(&c.to_string())).collect()),
        ));
    }
    if let Some(m) = run {
        let mut runf = vec![
            ("throughput_qpm", num(m.throughput_qpm)),
            ("p50_latency_s", num(m.p50_latency_s)),
            ("p99_latency_s", num(m.p99_latency_s)),
            ("n_requests", num(m.n_requests as f64)),
            ("failovers", num(m.failovers as f64)),
            ("hedges", num(m.hedges as f64)),
            ("hedged_slots", num(m.hedged_slots as f64)),
            ("requeue_retries", num(m.requeue_retries as f64)),
        ];
        if let Some(pb) = &m.phases {
            runf.push(("phases", pb.to_json()));
        }
        fields.push(("run", obj(runf)));
    }
    obj(fields)
}

/// `--watch`: one human summary line per snapshot instant.
fn print_watch(
    t: f64,
    regs: Option<&(MetricsRegistry, Vec<MetricsRegistry>)>,
    gauges: &[(f64, usize)],
    inflight: usize,
) {
    let (completed, failovers, hedges) = regs
        .map(|(f, _)| (f.counter("completed"), f.counter("failovers"), f.counter("hedges")))
        .unwrap_or((0, 0, 0));
    let backlog: f64 = gauges.iter().map(|(b, _)| *b).sum();
    let up: usize = gauges.iter().map(|(_, u)| *u).sum();
    println!(
        "[watch t={t:7.2}] inflight {inflight:>3} | done {completed:>4} | backlog {backlog:6.2}s \
         | up edges {up} | failovers {failovers} | hedges {hedges}"
    );
}

/// One line per streamed response event (`--stream`).
fn print_event(ev: &pice::serve::ResponseEvent) {
    let clip = |s: &str| -> String {
        let mut out: String = s.chars().take(56).collect();
        if s.chars().count() > 56 {
            out.push('…');
        }
        out
    };
    match &ev.kind {
        ResponseEventKind::Admitted { mode } => {
            println!("[t={:8.2}] req {:>3} admitted ({mode:?})", ev.t, ev.rid)
        }
        ResponseEventKind::SketchReady { text } => {
            println!("[t={:8.2}] req {:>3} sketch    | {}", ev.t, ev.rid, clip(text))
        }
        ResponseEventKind::ExpansionChunk { slot, text } => {
            println!("[t={:8.2}] req {:>3} expand #{slot} | {}", ev.t, ev.rid, clip(text))
        }
        ResponseEventKind::Final { trace } => println!(
            "[t={:8.2}] req {:>3} FINAL     | {:.2}s e2e, winner {}",
            ev.t,
            ev.rid,
            trace.latency(),
            if trace.winner_model.is_empty() { "cloud" } else { &trace.winner_model }
        ),
        ResponseEventKind::Rejected { reason } => {
            println!("[t={:8.2}] req {:>3} REJECTED  | {}", ev.t, ev.rid, reason)
        }
    }
}

fn models() -> Result<(), String> {
    let env = Env::load()?;
    println!(
        "{:<14} {:>9} {:>10} {:>6} | {:>8} {:>7} {:>8} {:>9}",
        "model", "speed t/s", "memory GB", "MMLU", "d_model", "layers", "params", "eval acc"
    );
    for m in &env.registry.models {
        println!(
            "{:<14} {:>9.2} {:>10.2} {:>6.1} | {:>8} {:>7} {:>8} {:>9.3}",
            m.name, m.speed_tps, m.memory_gb, m.mmlu, m.d_model, m.n_layers, m.n_params, m.eval_accuracy
        );
    }
    Ok(())
}

fn profile(args: &Args) -> Result<(), String> {
    let env = Env::load()?;
    let cluster = Cluster::testbed(args.opt_usize("edges", 4));
    let devices: Vec<&DeviceSpec> =
        std::iter::once(&cluster.cloud).chain(cluster.edges.iter().take(1)).collect();
    let models: Vec<&ModelInfo> = env.registry.models.iter().collect();
    let prof = OfflineProfile::profile_batched(&devices, &models, 16);
    println!("offline latency fits f(l) = a + b*l  [seconds; cloud at batch 16]");
    for d in &devices {
        for m in &models {
            if let Some(fit) = prof.f(&d.name, &m.name) {
                println!("  {:<8} {:<14} a={:>7.3}  b={:>8.5}  f(500)={:>7.1}s", d.name, m.name, fit.a, fit.b, fit.eval(500));
            } else {
                println!("  {:<8} {:<14} OOM", d.name, m.name);
            }
        }
    }
    for slm in env.registry.slms_for("qwen72b-sim") {
        if let Some(c) = prof.cost_coefficient("cloud-0", "qwen72b-sim", "edge-0", &slm.name) {
            println!("cost coefficient c (72B cloud vs {} edge) = {c:.2}", slm.name);
        }
    }
    Ok(())
}

fn finetune(args: &Args) -> Result<(), String> {
    let mut env = Env::load()?;
    let trainer = Trainer {
        cfg: TrainerCfg {
            pairs_per_category: args.opt_usize("pairs", 8),
            rl_steps: args.opt_usize("steps", 30),
            ..Default::default()
        },
        corpus: env.corpus.clone(),
        tok: &env.tok,
    };
    let out = trainer.run(env.backend.as_mut())?;
    println!(
        "reward model: {} pairs, train loss {:.3}, holdout accuracy {:.2}",
        out.n_pairs, out.rm_train_loss, out.rm_holdout_acc
    );
    println!("fine-tuned keep-fractions per category:");
    for (cat, frac) in &out.policy.keep_frac {
        println!("  {cat:<16} {frac:.2}");
    }
    Ok(())
}

fn eval(args: &Args) -> Result<(), String> {
    let model = args.opt_str("model", "llama70b-sim").to_string();
    let n = args.opt_usize("n", 40);
    let mut env = Env::load()?;
    let rpm = env.paper_rpm(&model);
    let judge = Judge::fit(&env.corpus);
    println!("{:<11} {:>10} {:>9} {:>8}", "system", "thpt(q/m)", "lat(s)", "quality");
    for (name, result) in env.run_all_systems(&model, rpm, n, 11) {
        match result {
            Err(e) => println!("{name:<11} {e}"),
            Ok((m, traces)) => {
                let scores: Vec<f64> = traces
                    .iter()
                    .filter_map(|t| {
                        env.corpus.get(t.question_id).map(|q| judge.score(q, &t.answer).overall)
                    })
                    .collect();
                println!(
                    "{name:<11} {:>10.2} {:>9.2} {:>8.2}",
                    m.throughput_qpm, m.avg_latency_s, stats::mean(&scores)
                );
            }
        }
    }
    Ok(())
}
