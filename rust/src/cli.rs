//! Tiny argument parser (no clap in the offline image).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = raw.peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_options() {
        // note: "--verbose extra" would bind "extra" as the value of
        // --verbose (greedy option parsing); flags go last or standalone.
        let a = parse("serve --rpm 30 --model qwen72b-sim extra --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt_f64("rpm", 0.0), 30.0);
        assert_eq!(a.opt_str("model", "x"), "qwen72b-sim");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse("bench --n=12 table3");
        assert_eq!(a.opt_usize("n", 0), 12);
        assert_eq!(a.positional, vec!["table3"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("eval --smoke");
        assert!(a.has_flag("smoke"));
        assert!(a.opt("smoke").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert!(a.subcommand.is_none());
        assert_eq!(a.opt_f64("rpm", 42.0), 42.0);
    }
}
