//! Tiny argument parser (no clap in the offline image).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = raw.peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Strict validation: every parsed `--key value` must name a known
    /// option and every bare `--flag` a known flag. Typos error out with
    /// the valid set listed instead of being silently ignored; a known flag
    /// given a value (or a known option missing one) gets a targeted
    /// message.
    pub fn validate(&self, options: &[&str], flags: &[&str]) -> Result<(), String> {
        let valid_list = || {
            let mut v: Vec<String> = options.iter().map(|o| format!("--{o} <value>")).collect();
            v.extend(flags.iter().map(|f| format!("--{f}")));
            if v.is_empty() {
                "none".to_string()
            } else {
                v.join(", ")
            }
        };
        for k in self.options.keys() {
            if options.contains(&k.as_str()) {
                continue;
            }
            if flags.contains(&k.as_str()) {
                return Err(format!("flag --{k} does not take a value"));
            }
            return Err(format!("unknown option --{k}; valid options: {}", valid_list()));
        }
        for f in &self.flags {
            if flags.contains(&f.as_str()) {
                continue;
            }
            if options.contains(&f.as_str()) {
                return Err(format!("option --{f} requires a value"));
            }
            return Err(format!("unknown flag --{f}; valid options: {}", valid_list()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_options() {
        // note: "--verbose extra" would bind "extra" as the value of
        // --verbose (greedy option parsing); flags go last or standalone.
        let a = parse("serve --rpm 30 --model qwen72b-sim extra --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.opt_f64("rpm", 0.0), 30.0);
        assert_eq!(a.opt_str("model", "x"), "qwen72b-sim");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse("bench --n=12 table3");
        assert_eq!(a.opt_usize("n", 0), 12);
        assert_eq!(a.positional, vec!["table3"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("eval --smoke");
        assert!(a.has_flag("smoke"));
        assert!(a.opt("smoke").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert!(a.subcommand.is_none());
        assert_eq!(a.opt_f64("rpm", 42.0), 42.0);
    }

    #[test]
    fn validate_accepts_known_names() {
        let a = parse("serve --rpm 30 --model qwen72b-sim --quiet");
        assert!(a.validate(&["rpm", "model", "n"], &["quiet"]).is_ok());
    }

    #[test]
    fn validate_rejects_unknown_option_listing_valid_set() {
        let a = parse("serve --rmp 30");
        let err = a.validate(&["rpm", "model"], &["quiet"]).unwrap_err();
        assert!(err.contains("--rmp"), "{err}");
        assert!(err.contains("--rpm"), "error must list valid options: {err}");
        assert!(err.contains("--quiet"), "error must list valid flags: {err}");
    }

    #[test]
    fn validate_rejects_unknown_flag() {
        let a = parse("eval --smoek");
        let err = a.validate(&[], &["smoke"]).unwrap_err();
        assert!(err.contains("--smoek"), "{err}");
        assert!(err.contains("--smoke"), "{err}");
    }

    #[test]
    fn validate_flags_option_value_mismatches() {
        // a known flag handed a value (greedy parse binds it)
        let a = parse("serve --quiet yes");
        let err = a.validate(&["rpm"], &["quiet"]).unwrap_err();
        assert!(err.contains("does not take a value"), "{err}");
        // a known option left bare
        let a = parse("serve --rpm");
        let err = a.validate(&["rpm"], &["quiet"]).unwrap_err();
        assert!(err.contains("requires a value"), "{err}");
    }
}
