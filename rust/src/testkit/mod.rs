//! Minimal property-testing harness (no proptest crate in the offline
//! image). Deterministic: every failure reports the case seed so it can be
//! replayed exactly.
//!
//! ```ignore
//! use pice::testkit::forall;
//! forall(100, |rng| {
//!     let x = rng.below(1000) as f64;
//!     assert!(x >= 0.0);
//! });
//! ```

use crate::util::rng::Rng;

/// Run `f` on `n` independently-seeded RNG streams; panics with the case
/// seed on the first failure.
pub fn forall(n: usize, mut f: impl FnMut(&mut Rng)) {
    forall_seeded(0xDEFA017, n, &mut f)
}

pub fn forall_seeded(base_seed: u64, n: usize, f: &mut impl FnMut(&mut Rng)) {
    for case in 0..n {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("testkit: case {case} failed (replay with seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generators for common shapes.
pub struct Gen;

impl Gen {
    /// Non-empty vec of usize in [lo, hi).
    pub fn lens(rng: &mut Rng, max_n: usize, lo: usize, hi: usize) -> Vec<usize> {
        let n = 1 + rng.below(max_n.max(1));
        (0..n).map(|_| lo + rng.below(hi - lo)).collect()
    }

    /// Token sequence with ids in [10, vocab).
    pub fn tokens(rng: &mut Rng, max_n: usize, vocab: u32) -> Vec<u32> {
        let n = 1 + rng.below(max_n.max(1));
        (0..n).map(|_| 10 + (rng.next_u64() % (vocab as u64 - 10)) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failures() {
        forall(10, |rng| {
            assert!(rng.below(10) < 5, "intentional");
        });
    }

    #[test]
    fn gens_in_range() {
        forall(50, |rng| {
            let ls = Gen::lens(rng, 8, 2, 30);
            assert!(!ls.is_empty() && ls.len() <= 8);
            assert!(ls.iter().all(|&l| (2..30).contains(&l)));
            let ts = Gen::tokens(rng, 16, 100);
            assert!(ts.iter().all(|&t| (10..100).contains(&t)));
        });
    }
}
