//! ROUGE metrics over token-id sequences.
//!
//! Rouge-1 feeds the ensemble confidence (Eq. 3); Rouge-L feeds the
//! fine-tuning preference labeler (§IV-D) and the judge.

use std::collections::HashMap;

/// Rouge-1 F1: unigram overlap between candidate and reference.
pub fn rouge1_f1(candidate: &[u32], reference: &[u32]) -> f64 {
    if candidate.is_empty() || reference.is_empty() {
        return 0.0;
    }
    let mut ref_counts: HashMap<u32, usize> = HashMap::new();
    for &t in reference {
        *ref_counts.entry(t).or_insert(0) += 1;
    }
    let mut overlap = 0usize;
    for &t in candidate {
        if let Some(c) = ref_counts.get_mut(&t) {
            if *c > 0 {
                *c -= 1;
                overlap += 1;
            }
        }
    }
    let p = overlap as f64 / candidate.len() as f64;
    let r = overlap as f64 / reference.len() as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Longest common subsequence length (O(n*m) DP, rolling row). This is the
/// naive reference implementation; the hot path goes through
/// [`lcs_len_trimmed`], which strips the shared prefix/suffix first.
pub fn lcs_len(a: &[u32], b: &[u32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { cur[j].max(prev[j + 1]) };
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// LCS length with the shared prefix and suffix stripped before the DP:
/// `LCS(p·x·s, p·y·s) = |p| + LCS(x, y) + |s|`, so near-identical pairs —
/// the common case when scoring high-quality candidates against their
/// reference — collapse from O(n·m) to near-linear. Equals [`lcs_len`] on
/// every input.
pub fn lcs_len_trimmed(a: &[u32], b: &[u32]) -> usize {
    let n = a.len().min(b.len());
    let mut p = 0usize;
    while p < n && a[p] == b[p] {
        p += 1;
    }
    let (a, b) = (&a[p..], &b[p..]);
    let m = a.len().min(b.len());
    let mut s = 0usize;
    while s < m && a[a.len() - 1 - s] == b[b.len() - 1 - s] {
        s += 1;
    }
    p + s + lcs_len(&a[..a.len() - s], &b[..b.len() - s])
}

/// Rouge-L F1 (LCS-based, via the prefix/suffix-trimmed DP).
pub fn rouge_l_f1(candidate: &[u32], reference: &[u32]) -> f64 {
    if candidate.is_empty() || reference.is_empty() {
        return 0.0;
    }
    let l = lcs_len_trimmed(candidate, reference) as f64;
    let p = l / candidate.len() as f64;
    let r = l / reference.len() as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

/// Distinct-n: fraction of unique n-grams (the judge's diversity proxy).
pub fn distinct_n(tokens: &[u32], n: usize) -> f64 {
    if tokens.len() < n {
        return 0.0;
    }
    let total = tokens.len() - n + 1;
    let mut seen = std::collections::HashSet::new();
    for w in tokens.windows(n) {
        seen.insert(w.to_vec());
    }
    seen.len() as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_one() {
        let a = [1, 2, 3, 4];
        assert!((rouge1_f1(&a, &a) - 1.0).abs() < 1e-12);
        assert!((rouge_l_f1(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_is_zero() {
        assert_eq!(rouge1_f1(&[1, 2], &[3, 4]), 0.0);
        assert_eq!(rouge_l_f1(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn rouge1_counts_clipped() {
        // candidate repeats a token more than the reference contains it
        let c = [5, 5, 5, 5];
        let r = [5, 1];
        // overlap clipped to 1; p=0.25, r=0.5 -> f1 = 1/3
        assert!((rouge1_f1(&c, &r) - (2.0 * 0.25 * 0.5 / 0.75)).abs() < 1e-12);
    }

    #[test]
    fn lcs_basic() {
        assert_eq!(lcs_len(&[1, 2, 3, 4, 5], &[2, 4, 5]), 3);
        assert_eq!(lcs_len(&[1, 2, 3], &[3, 2, 1]), 1);
        assert_eq!(lcs_len(&[], &[1]), 0);
    }

    #[test]
    fn trimmed_lcs_equals_naive() {
        let cases: [(&[u32], &[u32]); 7] = [
            (&[1, 2, 3, 4, 5], &[2, 4, 5]),
            (&[1, 2, 3], &[3, 2, 1]),
            (&[1, 2, 3, 4], &[1, 2, 3, 4]),
            (&[1, 2, 9, 4, 5], &[1, 2, 7, 4, 5]),
            (&[1, 1, 1], &[1, 1]),
            (&[5, 6], &[7, 8]),
            (&[], &[1, 2]),
        ];
        for (a, b) in cases {
            assert_eq!(lcs_len_trimmed(a, b), lcs_len(a, b), "a={a:?} b={b:?}");
            assert_eq!(lcs_len_trimmed(b, a), lcs_len(b, a), "b={b:?} a={a:?}");
        }
    }

    #[test]
    fn order_matters_for_l_not_1() {
        let a = [1, 2, 3, 4];
        let rev = [4, 3, 2, 1];
        assert!((rouge1_f1(&a, &rev) - 1.0).abs() < 1e-12);
        assert!(rouge_l_f1(&a, &rev) < 0.5);
    }

    #[test]
    fn distinct_bounds() {
        assert!((distinct_n(&[1, 2, 3, 4], 1) - 1.0).abs() < 1e-12);
        let rep = [7u32; 10];
        assert!(distinct_n(&rep, 2) < 0.2);
    }
}
