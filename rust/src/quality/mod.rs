//! Response-quality measurement: ROUGE, perplexity accounting, and the
//! deterministic judge (FastChat/LLMZoo substitute — DESIGN.md §2).

pub mod judge;
pub mod rouge;

/// Perplexity from accumulated token log-probabilities (natural log):
/// ppl = exp(-mean(logp)). The ensemble confidence (Eq. 3) uses the
/// equivalent base-2 form 2^(mean log2 p) — see `ensemble::confidence`.
pub fn perplexity(logps: &[f64]) -> f64 {
    if logps.is_empty() {
        return f64::INFINITY;
    }
    let mean = logps.iter().sum::<f64>() / logps.len() as f64;
    (-mean).exp()
}

/// Geometric-mean token probability, 2^(1/N Σ log2 p) — the first term of
/// the paper's confidence formula. Equal to 1/perplexity.
pub fn mean_prob(logps: &[f64]) -> f64 {
    if logps.is_empty() {
        return 0.0;
    }
    let mean = logps.iter().sum::<f64>() / logps.len() as f64;
    mean.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_distribution_ppl() {
        // logp = ln(1/4) per token -> ppl = 4
        let lp = vec![(0.25f64).ln(); 10];
        assert!((perplexity(&lp) - 4.0).abs() < 1e-9);
        assert!((mean_prob(&lp) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn empty_degenerate() {
        assert!(perplexity(&[]).is_infinite());
        assert_eq!(mean_prob(&[]), 0.0);
    }

    #[test]
    fn certain_model_ppl_one() {
        let lp = vec![0.0; 5];
        assert!((perplexity(&lp) - 1.0).abs() < 1e-12);
    }
}
