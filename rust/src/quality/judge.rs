//! Deterministic response-quality judge — the FastChat/LLMZoo substitute.
//!
//! The paper scores answers with GPT-3.5-turbo on a 1-10 scale (FastChat)
//! and ranks four systems on five dimensions (LLMZoo: diversity, relevance,
//! immersion, coherence, integrity). An LLM judge is itself a proxy; we
//! substitute transparent proxies computed against the corpus:
//!
//!   relevance  — Rouge-1 vs the reference answer
//!   coherence  — mean bigram log-likelihood under a corpus bigram model
//!   diversity  — distinct-2 of the answer
//!   immersion  — fraction of tokens in the question category's vocabulary
//!   integrity  — fraction of reference sketch points covered by the answer
//!   overall    — calibrated 1-10 blend of the five
//!
//! Rankings are computed per-question across competing systems, exactly as
//! LLMZoo does (rank 1 = best, ties share the better rank).

use std::collections::{BTreeMap, HashMap, HashSet};

use super::rouge::{distinct_n, rouge1_f1};
use crate::corpus::{Corpus, Question};

#[derive(Clone, Copy, Debug, Default)]
pub struct Scores {
    pub overall: f64, // 1..10
    pub relevance: f64,
    pub coherence: f64,
    pub diversity: f64,
    pub immersion: f64,
    pub integrity: f64,
}

impl Scores {
    pub fn dims(&self) -> [f64; 5] {
        [self.diversity, self.relevance, self.immersion, self.coherence, self.integrity]
    }
}

pub const DIM_NAMES: [&str; 5] =
    ["diversity", "relevance", "immersion", "coherence", "integrity"];

/// Corpus-fitted judge model.
pub struct Judge {
    bigram_logp: HashMap<(u32, u32), f64>,
    unigram_logp: HashMap<u32, f64>,
    category_vocab: BTreeMap<String, HashSet<u32>>,
    fallback_logp: f64,
}

impl Judge {
    /// Fit bigram statistics + per-category vocabularies on the corpus
    /// reference answers (train split only — the judge must not memorize the
    /// eval answers it scores against; references enter only through rouge).
    pub fn fit(corpus: &Corpus) -> Judge {
        let mut big: HashMap<(u32, u32), usize> = HashMap::new();
        let mut uni: HashMap<u32, usize> = HashMap::new();
        let mut category_vocab: BTreeMap<String, HashSet<u32>> = BTreeMap::new();
        let mut total = 0usize;
        for q in &corpus.questions {
            let toks = q.answer_tokens();
            let cv = category_vocab.entry(q.category.clone()).or_default();
            for &t in &toks {
                *uni.entry(t).or_insert(0) += 1;
                cv.insert(t);
                total += 1;
            }
            for w in toks.windows(2) {
                *big.entry((w[0], w[1])).or_insert(0) += 1;
            }
        }
        let fallback_logp = -8.0;
        let bigram_logp = big
            .iter()
            .map(|(&k, &c)| {
                let prior = *uni.get(&k.0).unwrap_or(&1) as f64;
                (k, ((c as f64) / prior).ln())
            })
            .collect();
        let unigram_logp = uni
            .iter()
            .map(|(&t, &c)| (t, ((c as f64) / (total.max(1) as f64)).ln()))
            .collect();
        Judge { bigram_logp, unigram_logp, category_vocab, fallback_logp }
    }

    fn coherence(&self, tokens: &[u32]) -> f64 {
        if tokens.len() < 2 {
            return 0.0;
        }
        let mut lp = 0.0;
        for w in tokens.windows(2) {
            lp += self
                .bigram_logp
                .get(&(w[0], w[1]))
                .copied()
                .unwrap_or(self.fallback_logp);
        }
        let mean = lp / (tokens.len() - 1) as f64;
        // squash mean logp (~[-8, 0]) into [0, 1]
        ((mean - self.fallback_logp) / -self.fallback_logp).clamp(0.0, 1.0)
    }

    fn immersion(&self, category: &str, tokens: &[u32]) -> f64 {
        let Some(vocab) = self.category_vocab.get(category) else {
            return 0.0;
        };
        if tokens.is_empty() {
            return 0.0;
        }
        tokens.iter().filter(|t| vocab.contains(t)).count() as f64 / tokens.len() as f64
    }

    fn integrity(&self, q: &Question, tokens: &[u32]) -> f64 {
        if q.sentences.is_empty() {
            return 0.0;
        }
        let present: HashSet<u32> = tokens.iter().copied().collect();
        let covered = q
            .sentences
            .iter()
            .filter(|s| {
                let hits = s.sketch.iter().filter(|t| present.contains(t)).count();
                hits * 2 >= s.sketch.len()
            })
            .count();
        covered as f64 / q.sentences.len() as f64
    }

    /// Score one answer against its question's reference.
    pub fn score(&self, q: &Question, answer: &[u32]) -> Scores {
        let reference = q.answer_tokens();
        let relevance = rouge1_f1(answer, &reference);
        let coherence = self.coherence(answer);
        let diversity = distinct_n(answer, 2);
        let immersion = self.immersion(&q.category, answer);
        let integrity = self.integrity(q, answer);
        // Length-adequacy damper: one-word answers shouldn't score well even
        // if that word overlaps the reference.
        let len_ok = (answer.len() as f64 / reference.len().max(1) as f64).clamp(0.0, 1.2);
        let adequacy = len_ok.min(1.0).powf(0.5);
        let blend = 0.34 * relevance + 0.22 * integrity + 0.16 * coherence
            + 0.14 * immersion + 0.14 * diversity;
        let overall = (1.0 + 9.0 * blend * adequacy).clamp(1.0, 10.0);
        Scores { overall, relevance, coherence, diversity, immersion, integrity }
    }

    /// Unigram log-probability of a token (perplexity fallbacks, tests).
    pub fn unigram_logp(&self, t: u32) -> f64 {
        self.unigram_logp.get(&t).copied().unwrap_or(self.fallback_logp)
    }
}

/// Per-question LLMZoo-style ranks across systems (1 = best; ties share the
/// better rank, as in "rank of equal values is the min rank").
pub fn rank_dims(per_system: &[Scores]) -> Vec<[f64; 5]> {
    let n = per_system.len();
    let mut ranks = vec![[0.0f64; 5]; n];
    for d in 0..5 {
        let vals: Vec<f64> = per_system.iter().map(|s| s.dims()[d]).collect();
        for i in 0..n {
            let better = vals.iter().filter(|&&v| v > vals[i] + 1e-12).count();
            ranks[i][d] = (better + 1) as f64;
        }
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests_support::toy_corpus;

    #[test]
    fn reference_scores_high() {
        let (c, _tok) = toy_corpus();
        let judge = Judge::fit(&c);
        let q = &c.questions[0];
        let reference = q.answer_tokens();
        let s = judge.score(q, &reference);
        assert!(s.overall > 7.0, "reference answer scored {}", s.overall);
        assert!((s.relevance - 1.0).abs() < 1e-9);
        assert!((s.integrity - 1.0).abs() < 1e-9);
    }

    #[test]
    fn garbage_scores_low() {
        let (c, _tok) = toy_corpus();
        let judge = Judge::fit(&c);
        let q = &c.questions[0];
        let garbage = vec![9u32; 3];
        let s = judge.score(q, &garbage);
        assert!(s.overall < 4.0, "garbage scored {}", s.overall);
    }

    #[test]
    fn empty_answer_minimum() {
        let (c, _tok) = toy_corpus();
        let judge = Judge::fit(&c);
        let q = &c.questions[0];
        let s = judge.score(q, &[]);
        assert!((s.overall - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ranks_order_correct() {
        let hi = Scores { relevance: 0.9, diversity: 0.9, immersion: 0.9, coherence: 0.9, integrity: 0.9, overall: 9.0 };
        let lo = Scores { relevance: 0.1, diversity: 0.1, immersion: 0.1, coherence: 0.1, integrity: 0.1, overall: 2.0 };
        let ranks = rank_dims(&[lo, hi]);
        assert_eq!(ranks[1], [1.0; 5]);
        assert_eq!(ranks[0], [2.0; 5]);
    }

    #[test]
    fn tied_share_best_rank() {
        let s = Scores { relevance: 0.5, diversity: 0.5, immersion: 0.5, coherence: 0.5, integrity: 0.5, overall: 5.0 };
        let ranks = rank_dims(&[s, s]);
        assert_eq!(ranks[0], [1.0; 5]);
        assert_eq!(ranks[1], [1.0; 5]);
    }
}
