//! Execution optimizer: semantic-level parallelism planning for edge
//! expansion (paper §IV-B).
//!
//! Each sketch sentence expands independently, so a k-sentence sketch admits
//! up to k-way parallelism — but (1) uneven sentence lengths cause batch
//! stragglers and (2) every parallel lane re-processes the whole sketch
//! prompt (KV-cache overhead). The paper's fix is *binary-tree merging*:
//! sort sentences by length, pair longest-with-shortest, and recursively
//! halve the number of lanes while the latency constraint still holds.

/// A lane: indices of sketch sentences expanded sequentially on one stream.
pub type Group = Vec<usize>;

/// Cost model for one candidate grouping, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct EdgeCostModel {
    /// per-token decode latency at parallelism 1
    pub token_s: f64,
    /// marginal per-token slowdown per extra concurrent lane
    pub batch_slowdown: f64,
    /// prompt (sketch) tokens re-processed per lane
    pub prompt_tokens: usize,
    /// prefill tokens/s relative to decode (prefill is ~8x faster)
    pub prefill_speedup: f64,
}

impl EdgeCostModel {
    /// Wall-clock for expanding lanes concurrently: the slowest lane's
    /// decode tokens + one prompt prefill per lane, at batch-p token rate.
    pub fn wall_clock(&self, groups: &[Group], exp_lens: &[usize]) -> f64 {
        if groups.is_empty() {
            return 0.0;
        }
        let p = groups.len();
        let tok = self.token_s * (1.0 + self.batch_slowdown * (p - 1) as f64);
        let prefill = self.prompt_tokens as f64 * tok / self.prefill_speedup;
        groups
            .iter()
            .map(|g| {
                let decode: usize = g.iter().map(|&i| exp_lens[i]).sum();
                prefill + decode as f64 * tok
            })
            .fold(0.0, f64::max)
    }

    /// Total device-seconds consumed (efficiency; prompt overhead included).
    pub fn device_seconds(&self, groups: &[Group], exp_lens: &[usize]) -> f64 {
        let p = groups.len().max(1);
        let tok = self.token_s * (1.0 + self.batch_slowdown * (p - 1) as f64);
        let prefill = self.prompt_tokens as f64 * tok / self.prefill_speedup;
        groups
            .iter()
            .map(|g| prefill + g.iter().map(|&i| exp_lens[i]).sum::<usize>() as f64 * tok)
            .sum()
    }
}

/// One binary-tree merge step: sort lanes by total length, pair longest with
/// shortest (paper: (r1, rk), (r2, r(k-1)), ...).
pub fn merge_once(groups: &[Group], exp_lens: &[usize]) -> Vec<Group> {
    let mut order: Vec<usize> = (0..groups.len()).collect();
    let glen = |g: &Group| -> usize { g.iter().map(|&i| exp_lens[i]).sum() };
    order.sort_by_key(|&gi| std::cmp::Reverse(glen(&groups[gi])));
    let mut out = Vec::with_capacity(groups.len().div_ceil(2));
    let (mut lo, mut hi) = (0usize, order.len());
    while lo < hi {
        if hi - lo == 1 {
            out.push(groups[order[lo]].clone());
            lo += 1;
        } else {
            let mut merged = groups[order[lo]].clone();
            merged.extend_from_slice(&groups[order[hi - 1]]);
            out.push(merged);
            lo += 1;
            hi -= 1;
        }
    }
    out
}

/// Plan lanes for expanding `exp_lens` (predicted per-sentence expansion
/// lengths): start fully parallel (capped by the device memory ceiling
/// `p_max`), then merge while the wall-clock stays within `latency_budget`.
///
/// Returns the lane plan; `plan.len()` is the chosen parallelism degree.
pub fn plan_groups(
    exp_lens: &[usize],
    p_max: usize,
    latency_budget: f64,
    cost: &EdgeCostModel,
) -> Vec<Group> {
    let k = exp_lens.len();
    if k == 0 {
        return Vec::new();
    }
    // start: one sentence per lane, memory-capped via initial merges
    let mut groups: Vec<Group> = (0..k).map(|i| vec![i]).collect();
    while groups.len() > p_max.max(1) {
        groups = merge_once(&groups, exp_lens);
    }
    // recursively merge while the constraint still holds (merging halves the
    // prompt-overhead and KV footprint; stop before exceeding the budget)
    loop {
        if groups.len() <= 1 {
            break;
        }
        let candidate = merge_once(&groups, exp_lens);
        if cost.wall_clock(&candidate, exp_lens) <= latency_budget {
            groups = candidate;
        } else {
            break;
        }
    }
    groups
}

/// Batch-level wall clock: all jobs' lanes run concurrently on one device,
/// so the token-rate slowdown is a function of the TOTAL lane count. This is
/// the coupling the binary-tree merge exploits: merging one job's lanes
/// speeds up every other lane on the device.
pub fn batch_wall(plans: &[Vec<Group>], exp_lens: &[&[usize]], cost: &EdgeCostModel) -> f64 {
    let p_total: usize = plans.iter().map(Vec::len).sum();
    if p_total == 0 {
        return 0.0;
    }
    let tok = cost.token_s * (1.0 + cost.batch_slowdown * (p_total - 1) as f64);
    let prefill = cost.prompt_tokens as f64 * tok / cost.prefill_speedup;
    plans
        .iter()
        .zip(exp_lens)
        .map(|(groups, lens)| {
            groups
                .iter()
                .map(|g| prefill + g.iter().map(|&i| lens[i]).sum::<usize>() as f64 * tok)
                .fold(0.0, f64::max)
        })
        .fold(0.0, f64::max)
}

/// Plan lanes for a *batch* of expansion jobs sharing one edge device:
/// start fully parallel, then greedily binary-merge the job with the most
/// lanes while that (a) is required to fit the memory ceiling `p_mem`, or
/// (b) strictly reduces the batch wall clock (contention vs serialization —
/// the interior optimum of the paper's Fig. 7a).
///
/// Returns (per-job lane plans, batch wall clock seconds).
pub fn plan_batch(
    exp_lens_per_job: &[&[usize]],
    p_mem: usize,
    cost: &EdgeCostModel,
) -> (Vec<Vec<Group>>, f64) {
    let mut plans: Vec<Vec<Group>> = exp_lens_per_job
        .iter()
        .map(|lens| (0..lens.len()).map(|i| vec![i]).collect())
        .collect();
    if plans.is_empty() {
        return (plans, 0.0);
    }
    loop {
        let p_total: usize = plans.iter().map(Vec::len).sum();
        let wall = batch_wall(&plans, exp_lens_per_job, cost);
        // candidate: merge the job with the most lanes
        let Some(j) = (0..plans.len())
            .filter(|&j| plans[j].len() > 1)
            .max_by_key(|&j| plans[j].len())
        else {
            return (plans, wall);
        };
        let mut cand = plans.clone();
        cand[j] = merge_once(&plans[j], exp_lens_per_job[j]);
        let cand_wall = batch_wall(&cand, exp_lens_per_job, cost);
        let over_mem = p_total > p_mem.max(1);
        if over_mem || cand_wall < wall {
            plans = cand;
        } else {
            return (plans, wall);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm(prompt: usize) -> EdgeCostModel {
        EdgeCostModel { token_s: 0.01, batch_slowdown: 0.06, prompt_tokens: prompt, prefill_speedup: 8.0 }
    }

    #[test]
    fn merge_pairs_longest_with_shortest() {
        let lens = [10, 1, 5, 2];
        let groups: Vec<Group> = (0..4).map(|i| vec![i]).collect();
        let merged = merge_once(&groups, &lens);
        assert_eq!(merged.len(), 2);
        // longest (idx 0, len 10) pairs with shortest (idx 1, len 1)
        let sums: Vec<usize> = merged.iter().map(|g| g.iter().map(|&i| lens[i]).sum()).collect();
        assert_eq!(sums, vec![11, 7]);
    }

    #[test]
    fn merging_balances_lanes() {
        let lens = [20, 2, 18, 4, 16, 6];
        let groups: Vec<Group> = (0..6).map(|i| vec![i]).collect();
        let merged = merge_once(&groups, &lens);
        let sums: Vec<usize> = merged.iter().map(|g| g.iter().map(|&i| lens[i]).sum()).collect();
        let spread = sums.iter().max().unwrap() - sums.iter().min().unwrap();
        assert!(spread <= 2, "unbalanced lanes: {sums:?}");
    }

    #[test]
    fn plan_respects_memory_cap() {
        let lens = vec![8; 12];
        let plan = plan_groups(&lens, 4, 1e9, &cm(30));
        assert!(plan.len() <= 4);
        // all sentences covered exactly once
        let mut seen: Vec<usize> = plan.iter().flatten().copied().collect();
        seen.sort();
        assert_eq!(seen, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn tight_budget_keeps_parallelism() {
        let lens = vec![10; 8];
        // budget only fits the fully-parallel plan
        let c = cm(4);
        let full = c.wall_clock(&(0..8).map(|i| vec![i]).collect::<Vec<_>>(), &lens);
        let plan = plan_groups(&lens, 16, full * 1.01, &c);
        assert_eq!(plan.len(), 8, "should not merge under a tight budget");
    }

    #[test]
    fn loose_budget_merges_down() {
        let lens = vec![10; 8];
        let plan = plan_groups(&lens, 16, 1e9, &cm(400));
        // huge prompt overhead + no deadline -> merge all the way down
        assert_eq!(plan.len(), 1);
    }

    #[test]
    fn prompt_overhead_discourages_parallelism() {
        let lens = vec![6; 6];
        let c_small = cm(2);
        let c_big = cm(300);
        let budget = 3.0;
        let p_small = plan_groups(&lens, 8, budget, &c_small).len();
        let p_big = plan_groups(&lens, 8, budget, &c_big).len();
        assert!(p_big <= p_small);
    }

    #[test]
    fn empty_input() {
        assert!(plan_groups(&[], 4, 1.0, &cm(10)).is_empty());
    }

    #[test]
    fn batch_plan_partitions_every_job() {
        let a = vec![10, 12, 8, 14];
        let b = vec![20, 4];
        let (plans, wall) = plan_batch(&[&a, &b], 16, &cm(30));
        assert_eq!(plans.len(), 2);
        assert!(wall > 0.0);
        for (plan, lens) in plans.iter().zip([&a, &b]) {
            let mut all: Vec<usize> = plan.iter().flatten().copied().collect();
            all.sort();
            assert_eq!(all, (0..lens.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn batch_plan_respects_memory_ceiling() {
        let lens: Vec<usize> = vec![10; 8];
        let jobs: Vec<&[usize]> = vec![&lens, &lens, &lens];
        let (plans, _) = plan_batch(&jobs, 6, &cm(20));
        let total: usize = plans.iter().map(Vec::len).sum();
        assert!(total <= 6, "total lanes {total}");
    }

    #[test]
    fn batch_plan_never_worse_than_fully_merged() {
        // min-wall planning must beat (or match) full serialization
        let lens: Vec<usize> = vec![15; 6];
        let jobs: Vec<&[usize]> = vec![&lens];
        let c = cm(10);
        let (_, wall) = plan_batch(&jobs, 64, &c);
        let merged: Vec<Vec<Group>> = vec![vec![(0..6).collect()]];
        let merged_wall = batch_wall(&merged, &jobs, &c);
        assert!(wall <= merged_wall + 1e-9, "{wall} > {merged_wall}");
    }

    #[test]
    fn heavy_prompt_overhead_prefers_fewer_lanes() {
        let lens: Vec<usize> = vec![6; 8];
        let jobs: Vec<&[usize]> = vec![&lens];
        let (small_prompt, _) = plan_batch(&jobs, 64, &cm(2));
        let (big_prompt, _) = plan_batch(&jobs, 64, &EdgeCostModel {
            token_s: 0.01,
            batch_slowdown: 0.5, // harsh contention
            prompt_tokens: 500,
            prefill_speedup: 2.0,
        });
        assert!(big_prompt.iter().map(Vec::len).sum::<usize>()
            <= small_prompt.iter().map(Vec::len).sum::<usize>());
    }
}
